"""End-to-end PTQ pipeline (the paper's LLM recipe, scaled to CPU):

  1. pretrain a small LM on the synthetic corpus (cached),
  2. block-by-block FlexRound reconstruction (per-channel asymmetric weights,
     per-tensor activations, QDrop setting — the LLaMA recipe of Table 7),
     with a per-site SiteRule keeping the first layer at 8-bit (the standard
     mixed-precision LLM recipe; pass --no-rules for uniform bits),
  3. export integer weights (QTensor), with per-block fault-tolerant
     checkpoints, and compare perplexity against the fp model and RTN.

    PYTHONPATH=src python examples/ptq_pipeline.py [--method flexround]

Any method registered via ``method_api.register_method`` is accepted by
--method; this script has no hard-coded method list.
"""
import argparse
import sys

import os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


from benchmarks import common
from repro.core import QuantRecipe, method_api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="flexround",
                    choices=list(method_api.available_methods()))
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--no-rules", action="store_true",
                    help="uniform precision (skip the W8 first-layer rule)")
    ap.add_argument("--auto-bits", type=float, default=None, metavar="AVG",
                    help="sensitivity-guided automatic mixed precision: "
                         "probe every site and allocate bit-widths to this "
                         "numel-weighted average (replaces the hand-written "
                         "W8 first-layer rule)")
    ap.add_argument("--ckpt", default="/tmp/ptq_ckpt")
    args = ap.parse_args()

    print("1) pretraining / loading cached bench LM ...")
    model, params = common.get_trained_lm()
    fp_ppl = common.eval_ppl(model, params)
    print(f"   fp perplexity: {fp_ppl:.3f}")

    # per-site rule: keep the most quantization-sensitive first layer at W8
    # (glob over site names; later rules would win over earlier ones). With
    # --auto-bits the hand-written rule is replaced by allocator-emitted ones.
    rules = () if (args.no_rules or args.auto_bits) else \
        ("layers.0.*:w_bits=8",)
    print(f"2) block-wise PTQ: {args.method}, W{args.w_bits} per-channel "
          f"asym + A8 per-tensor (QDrop setting), rules={rules}, "
          f"ckpt -> {args.ckpt}")
    recipe = QuantRecipe(method=args.method, setting="qdrop",
                         w_bits=args.w_bits, w_granularity="per_channel",
                         a_bits=8, iters=args.iters, lr=3e-3, batch_size=16,
                         rules=rules)
    from repro.data import CalibrationSet, SyntheticTokens
    from repro.core.reconstruct import quantize_blocks
    src = SyntheticTokens(vocab=common.BENCH_CFG.vocab, seq_len=common.SEQ)
    cal = CalibrationSet.build(src, 64)
    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)
    alloc_meta = None
    if args.auto_bits is not None:
        # probe -> solve -> rules: the automatic version of the W8 rule
        from repro.allocate import Budget, auto_allocate
        report = auto_allocate(blocks, recipe, x0,
                               Budget("avg_bits", args.auto_bits))
        print("   " + report.pretty().replace("\n", "\n   "))
        recipe = recipe.with_rules(*report.rules())
        alloc_meta = report.meta()
        report.save(args.ckpt)  # resume validates against this allocation
    finalized, astates, reports = quantize_blocks(
        blocks, recipe, x0, checkpoint_dir=args.ckpt,
        progress=lambda s: print("   " + s), allocation=alloc_meta)
    qparams = assemble(finalized)

    ppl = common.eval_ppl(model, qparams, astates=astates, recipe=recipe)
    print(f"3) quantized perplexity: {ppl:.3f} (fp {fp_ppl:.3f})")

    rtn_recipe = QuantRecipe(method="rtn", setting="qdrop",
                             w_bits=args.w_bits,
                             w_granularity="per_channel", a_bits=8, iters=1,
                             batch_size=16)
    rq, ra, _ = common.ptq(model, params, rtn_recipe)
    rtn_ppl = common.eval_ppl(model, rq, astates=ra, recipe=rtn_recipe)
    print(f"   RTN baseline perplexity: {rtn_ppl:.3f}")
    print("   (expected: flexround << rtn, close to fp — paper Tables 5/7)")


if __name__ == "__main__":
    main()
