"""Quickstart: FlexRound vs RTN/AdaRound on one transformer block.

    PYTHONPATH=src python examples/quickstart.py

Quantizes the weights of a single small transformer layer to 4 bits with
each rounding method, reconstructing the block output from 64 calibration
sequences (paper §3, Eq. 2), and prints the reconstruction errors.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import QuantRecipe, method_api
from repro.core.context import QuantCtx
from repro.core.reconstruct import finalize_block, reconstruct_block
from repro.models import build_model

CFG = ArchConfig(name="demo", family="dense", n_layers=2, d_model=128,
                 n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                 dtype="float32", attn_chunk=64, xent_chunk=64, remat=False)


def main():
    model = build_model(CFG)
    params = model.init(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (64, 32), 0, CFG.vocab)
    x0, blocks, _ = model.quant_blocks(params, calib)
    block = blocks[0]
    y_fp = block.apply(block.params, x0, QuantCtx(mode="fp"))

    print(f"block: {block.name}, sites: {list(block.sites)}")
    print(f"{'method':12s} {'recon before':>14s} {'recon after':>14s}")
    for method in method_api.available_methods():  # every registered method
        recipe = QuantRecipe(method=method, w_bits=4, w_symmetric=True,
                             a_bits=None, iters=200, lr=3e-3, batch_size=16)
        ws, _, rep = reconstruct_block(block, recipe, x0, y_fp,
                                       jax.random.key(2))
        deployed = finalize_block(block, recipe, ws, as_qtensor=False)
        y_q = block.apply(deployed, x0, QuantCtx(mode="fp"))
        err = float(jnp.mean((y_q - y_fp) ** 2))
        print(f"{method:12s} {rep.err_before:14.3e} {err:14.3e}")
    print("\nExpected: flexround <= adaround < adaquant << rtn (paper Table 2)")


if __name__ == "__main__":
    main()
