"""Serve a quantized model with batched requests (the e2e driver — the
paper's kind is PTQ-for-deployment, so serving is the dictated scenario).

  1. pretrain/load the small LM,
  2. FlexRound-quantize weights to int8 (weight-only, per-channel),
  3. run a batched serving engine: continuous prefill + decode over a queue
     of requests with mixed prompt lengths, measuring tokens/s for bf16 vs
     int8 vs int4 weights.

    PYTHONPATH=src python examples/serve_quantized.py [--tokens 32]
"""
import argparse
import sys

import os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import QuantRecipe
from repro.core.context import QuantCtx
from repro.obs.telemetry import Stopwatch


class ServingEngine:
    """Minimal batched engine: pad-batch prefill, lockstep decode."""

    def __init__(self, model, params, max_len=128, backend="auto"):
        self.model = model
        self.params = params
        self.max_len = max_len
        # kernel-backed deploy path: compiled Pallas on TPU, XLA refs on CPU
        ctx = QuantCtx(mode="deploy", backend=backend)
        self._prefill = jax.jit(
            lambda p, t, c: model.prefill(p, t, c, ctx))
        self._step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, ctx))

    def generate(self, prompts, n_tokens):
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = jnp.asarray([[0] * (S - len(p)) + list(p) for p in prompts],
                           jnp.int32)
        cache = self.model.init_cache(B, self.max_len)
        _, cache = self._prefill(self.params, toks, cache)
        out = []
        cur = toks[:, -1:]
        for t in range(n_tokens):
            logits, cache = self._step(self.params, cur, cache,
                                       jnp.int32(S + t))
            cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(cur)
        return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "xla"])
    args = ap.parse_args()

    model, params = common.get_trained_lm()
    rng = jax.random.key(7)
    prompts = [list(map(int, jax.random.randint(
        jax.random.fold_in(rng, i), (l,), 0, common.BENCH_CFG.vocab)))
        for i, l in enumerate([8, 12, 16, 9, 14, 10, 16, 8][:args.batch])]

    variants = {"bf16": params}
    for bits, tag in ((8, "int8"), (4, "int4")):
        recipe = QuantRecipe(method="flexround", w_bits=bits, a_bits=None,
                             w_granularity="per_channel", iters=80, lr=3e-3,
                             batch_size=16)
        qp, _, _ = common.ptq(model, params, recipe, as_qtensor=True)
        variants[tag] = qp

    ref = None
    for tag, p in variants.items():
        eng = ServingEngine(model, p, backend=args.backend)
        out = eng.generate(prompts, 4)  # warm compile
        sw = Stopwatch()
        out = eng.generate(prompts, args.tokens)
        tps = args.batch * args.tokens / sw.elapsed_s()
        if ref is None:
            ref = out
        agree = float(jnp.mean(out == ref))
        wbytes = sum(x.nbytes for x in jax.tree.leaves(p))
        print(f"{tag:5s}: {tps:8.1f} tok/s  weights={wbytes/2**20:6.1f} MiB  "
              f"greedy-token agreement vs bf16: {agree:.2%}")
    print("\nOn TPU the int8/int4 variants cut the decode memory-roofline "
          "term 2x/4x (see EXPERIMENTS.md §Perf); on CPU the win shows as "
          "weight-bytes. Token agreement ~1.0 validates the quantization.")


if __name__ == "__main__":
    main()
