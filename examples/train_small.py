"""Train a small LM end-to-end with the full substrate: data pipeline,
AdamW (optionally int8 moments), checkpoint/restart, straggler-tolerant
batch assembly — the training-side driver.

    PYTHONPATH=src python examples/train_small.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core.context import QuantCtx
from repro.data import StragglerPolicy, SyntheticTokens, assemble_global_batch
from repro.models import build_model
from repro.optim.adam import AdamConfig, adam_init, adam_update

CFG = ArchConfig(name="train-demo", family="dense", n_layers=4, d_model=128,
                 n_heads=4, n_kv_heads=2, d_ff=256, vocab=256,
                 dtype="float32", attn_chunk=64, xent_chunk=64, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/train_small_ckpt")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    args = ap.parse_args()

    model = build_model(CFG)
    opt_cfg = AdamConfig(lr=3e-3, grad_clip=1.0,
                         moment_dtype=args.moment_dtype)
    src = SyntheticTokens(vocab=CFG.vocab, seq_len=64, seed=0)
    mgr = CheckpointManager(args.ckpt, keep=2)
    n_hosts = 4
    policy = StragglerPolicy(min_fraction=0.5)

    state, meta = mgr.restore()
    if state is None:
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adam_init(params, opt_cfg),
                 "step": jnp.int32(0)}
        start = 0
    else:
        start = int(meta["step"])
        print(f"resumed from step {start}")

    @jax.jit
    def train_step(state, batch, weight):
        def loss_fn(p):
            loss, m = model.loss(p, batch, QuantCtx(mode="fp"))
            scale = weight.shape[0] / jnp.maximum(weight.sum(), 1.0)
            return loss * scale
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        params, opt, gnorm = adam_update(grads, state["opt"],
                                         state["params"], opt_cfg)
        return {"params": params, "opt": opt, "step": state["step"] + 1}, loss

    for step in range(start, args.steps):
        # per-host shards; every 37th step a host straggles past the deadline
        shards = [jax.tree.map(np.asarray,
                               src.batch(step, 16, host=h, n_hosts=n_hosts))
                  for h in range(n_hosts)]
        if step % 37 == 36:
            shards[step % n_hosts] = None
        batch, weight = assemble_global_batch(shards, policy)
        state, loss = train_step(state, batch, weight)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}")
        if step % 50 == 49:
            mgr.save(step + 1, state)
        if step == args.simulate_failure_at:
            print("simulated crash — rerun the same command to resume")
            return
    mgr.save(args.steps, state)
    print("done; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
