"""Shared benchmark substrate: a small pretrained LM + PTQ drivers.

The paper's metrics need a model whose task loss responds to quantization:
we pretrain a small transformer on the synthetic Markov corpus (data
pipeline) until it clearly beats the unigram floor, cache the checkpoint,
and measure perplexity deltas under each PTQ method — the scaled-down
analogue of the paper's ImageNet/GLUE/WikiText tables.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import load_pytree, save_pytree
from repro.configs.base import ArchConfig
from repro.core import QuantRecipe
from repro.core.context import QuantCtx
from repro.core.reconstruct import quantize_blocks
from repro.data import CalibrationSet, SyntheticTokens
from repro.models import build_model
from repro.optim.adam import AdamConfig, adam_init, adam_update

CACHE = os.path.join(os.path.dirname(__file__), ".bench_cache")

BENCH_CFG = ArchConfig(
    name="bench-lm", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=256, norm="rmsnorm", act="swiglu",
    dtype="float32", attn_chunk=64, xent_chunk=64, remat=False)

SEQ = 64
TRAIN_STEPS = 300
BATCH = 16


def get_trained_lm(steps: int = TRAIN_STEPS) -> Tuple[object, Dict]:
    """Returns (model, params) — pretrained small LM (cached on disk)."""
    model = build_model(BENCH_CFG)
    path = os.path.join(CACHE, f"bench_lm_{steps}")
    if os.path.isdir(path):
        params, _ = load_pytree(path)
        return model, jax.tree.map(jnp.asarray, params)
    src = SyntheticTokens(vocab=BENCH_CFG.vocab, seq_len=SEQ, seed=0)
    params = model.init(jax.random.key(0))
    opt_cfg = AdamConfig(lr=3e-3, grad_clip=1.0)
    opt = adam_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            loss, m = model.loss(p, batch, QuantCtx(mode="fp"))
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adam_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    for i in range(steps):
        params, opt, loss = step(params, opt, src.batch(i, BATCH))
    os.makedirs(CACHE, exist_ok=True)
    save_pytree(path, params)
    return model, params


def eval_ppl(model, params, n_batches: int = 8, ctx: Optional[QuantCtx] = None,
             astates=None, recipe=None) -> float:
    src = SyntheticTokens(vocab=BENCH_CFG.vocab, seq_len=SEQ, seed=99)
    ctx = ctx or QuantCtx(mode="fp")
    if astates is not None:
        ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates)
    tot, cnt = 0.0, 0
    for i in range(n_batches):
        batch = src.batch(50_000 + i, BATCH)
        loss, _ = model.loss(params, batch, ctx)
        tot += float(loss)
        cnt += 1
    return float(jnp.exp(tot / cnt))


def ptq(model, params, recipe: QuantRecipe, n_calib: int = 64,
        as_qtensor: bool = False):
    """Full PTQ of the bench LM; returns (quantized params, astates, reports)."""
    src = SyntheticTokens(vocab=BENCH_CFG.vocab, seq_len=SEQ, seed=0)
    cal = CalibrationSet.build(src, n_calib)
    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)
    finalized, astates, reports = quantize_blocks(
        blocks, recipe, x0, as_qtensor=as_qtensor)
    return assemble(finalized), astates, reports


def make_block_chain(n_blocks: int, d: int = 32, d_hidden: int = 64,
                     seed: int = 0):
    """Chain of structurally identical MLP blocks sharing one apply_key —
    the minimal stand-in for a transformer's L identical layers (used to
    show compile_count stays flat as the block count grows)."""
    from repro.core.reconstruct import BlockHandle, Site

    token = (object(),)  # fresh per chain; shared across its blocks
    blocks = []
    for i, key in enumerate(jax.random.split(jax.random.key(seed), n_blocks)):
        k1, k2 = jax.random.split(key)
        name = f"layers.{i}"
        params = {
            "w1": jax.random.normal(k1, (d, d_hidden), jnp.float32) * d**-0.5,
            "w2": jax.random.normal(k2, (d_hidden, d), jnp.float32) * d_hidden**-0.5,
        }

        def apply_fn(p, x, ctx, _n=name):
            h = jax.nn.gelu(ctx.linear(f"{_n}.w1", x, p["w1"]))
            return ctx.linear(f"{_n}.w2", h, p["w2"]) + x

        sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
        blocks.append(BlockHandle(name, params, apply_fn, sites,
                                  apply_key=token))
    return blocks


def timed_decode(model, params, ctx: QuantCtx, tokens, *, reps: int = 8
                 ) -> float:
    """Shared decode-timing protocol: jit prefill, one warm decode step,
    then ``reps`` timed steps. Returns us per decode step."""
    B, S = tokens.shape
    cache = model.init_cache(B, S + reps + 1)
    prefill = jax.jit(lambda p, t, c: model.prefill(p, t, c, ctx))
    _, cache = prefill(params, tokens, cache)
    step = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos, ctx))
    tok = tokens[:, -1:]
    logits, cache = step(params, tok, cache, jnp.int32(S))  # warm
    t0 = time.perf_counter()
    for i in range(reps):
        logits, cache = step(params, tok, cache, jnp.int32(S + 1 + i))
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / reps * 1e6


def timed(fn, *args, reps: int = 3) -> Tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
