"""One benchmark per paper table/figure (scaled-down CPU proxies).

Each function prints ``name,us_per_call,derived`` CSV rows. ``derived`` is
the table's quality metric (perplexity / recon error / shift stats); the
paper's qualitative ordering is what we validate (see EXPERIMENTS.md
§Paper-validation for the side-by-side with the paper's own numbers).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import QuantRecipe, flexround
from repro.core.context import QuantCtx
from repro.core.quant_config import QuantConfig


def _ppl_after(model, params0, recipe) -> Dict[str, float]:
    t0 = time.perf_counter()
    qparams, astates, reports = common.ptq(model, params0, recipe)
    wall = (time.perf_counter() - t0) * 1e6
    ppl = common.eval_ppl(model, qparams, astates=astates, recipe=recipe)
    err = sum(r.err_after for r in reports) / len(reports)
    return {"us": wall, "ppl": ppl, "recon_err": err}


def table1_ablation(out: List[str]):
    """Table 1: learnable s1 (abl.1) + s3 contribution (abl.2), W4 per-tensor
    symmetric, weights-only."""
    model, params = common.get_trained_lm()
    fp_ppl = common.eval_ppl(model, params)
    out.append(common.row("table1/full-precision", 0.0, f"ppl={fp_ppl:.3f}"))
    base = dict(method="flexround", w_bits=4, w_symmetric=True, a_bits=None,
                iters=200, lr=3e-3, batch_size=16)

    r = _ppl_after(model, params, QuantRecipe(**base))
    out.append(common.row("table1/flexround", r["us"],
                          f"ppl={r['ppl']:.3f};recon={r['recon_err']:.2e}"))

    orig = flexround.trainable
    try:  # Ablation 1: fixed s1
        flexround.trainable = lambda st: {k: (k not in ("zero", "s1"))
                                          for k in st}
        r = _ppl_after(model, params, QuantRecipe(**base))
        out.append(common.row("table1/ablation1-fixed-s1", r["us"],
                              f"ppl={r['ppl']:.3f};recon={r['recon_err']:.2e}"))
        # Ablation 2: without s3 (s4 n/a for linear)
        flexround.trainable = lambda st: {k: (k not in ("zero", "s3", "s4"))
                                          for k in st}
        r = _ppl_after(model, params, QuantRecipe(**base))
        out.append(common.row("table1/ablation2-no-s3", r["us"],
                              f"ppl={r['ppl']:.3f};recon={r['recon_err']:.2e}"))
    finally:
        flexround.trainable = orig


def table2_weights_only(out: List[str]):
    """Table 2: RTN/AdaQuant/AdaRound/FlexRound at W4/W3/W2 (weights only,
    per-tensor symmetric — the vision recipe applied to our LM)."""
    model, params = common.get_trained_lm()
    fp_ppl = common.eval_ppl(model, params)
    out.append(common.row("table2/full-precision", 0.0, f"ppl={fp_ppl:.3f}"))
    for bits in (4, 3, 2):
        for method in ("rtn", "adaquant", "adaround", "flexround"):
            recipe = QuantRecipe(method=method, w_bits=bits, w_symmetric=True,
                                 a_bits=None, iters=200, lr=3e-3,
                                 batch_size=16)
            r = _ppl_after(model, params, recipe)
            out.append(common.row(f"table2/W{bits}/{method}", r["us"],
                                  f"ppl={r['ppl']:.3f}"))


def table3_w_a(out: List[str]):
    """Table 3: weights+activations quantized; BRECQ vs QDrop settings."""
    model, params = common.get_trained_lm()
    for setting in ("brecq", "qdrop"):
        for method in ("adaround", "flexround"):
            recipe = QuantRecipe(method=method, setting=setting, w_bits=4,
                                 w_symmetric=True, a_bits=8, iters=200,
                                 lr=3e-3, batch_size=16)
            r = _ppl_after(model, params, recipe)
            out.append(common.row(f"table3/W4A8/{setting[0].upper()}+{method}",
                                  r["us"], f"ppl={r['ppl']:.3f}"))


def table5_lm_w8a8(out: List[str]):
    """Table 5 (GPT-Neo/OPT proxy): per-tensor asymmetric W8A8, layer-wise
    transformer-block reconstruction, PPL vs full precision."""
    model, params = common.get_trained_lm()
    fp_ppl = common.eval_ppl(model, params)
    out.append(common.row("table5/full-precision", 0.0, f"ppl={fp_ppl:.3f}"))
    for method in ("adaround", "flexround"):
        recipe = QuantRecipe(method=method, setting="qdrop", w_bits=8,
                             w_symmetric=False, a_bits=8, iters=150,
                             lr=5e-3, batch_size=16)
        r = _ppl_after(model, params, recipe)
        out.append(common.row(f"table5/W8A8/Q+{method}", r["us"],
                              f"ppl={r['ppl']:.3f}"))


def table7_llm_blockwise(out: List[str]):
    """Table 7/21 (LLaMA proxy): per-channel asymmetric weights, per-tensor
    activations, block-by-block reconstruction; also W4/16 weight-only."""
    model, params = common.get_trained_lm()
    fp_ppl = common.eval_ppl(model, params)
    out.append(common.row("table7/half-precision", 0.0, f"ppl={fp_ppl:.3f}"))
    for tag, kw in {
        "W8A8/Q+flexround": dict(w_bits=8, a_bits=8, setting="qdrop"),
        "W8A8/Q+adaround": dict(method="adaround", w_bits=8, a_bits=8,
                                setting="qdrop"),
        "W4A16/B+flexround": dict(w_bits=4, a_bits=None, setting="brecq"),
        "W4A16/B+adaround": dict(method="adaround", w_bits=4, a_bits=None,
                                 setting="brecq"),
    }.items():
        recipe = QuantRecipe(method=kw.pop("method", "flexround"),
                             w_granularity="per_channel", iters=200, lr=3e-3,
                             batch_size=16, **kw)
        r = _ppl_after(model, params, recipe)
        out.append(common.row(f"table7/{tag}", r["us"],
                              f"ppl={r['ppl']:.3f}"))


def fig3_grid_shifts(out: List[str]):
    """Fig 3/5: fraction of weights shifted >1 grid step vs RTN, and the
    more-shifts-at-higher-bits trend."""
    model, params = common.get_trained_lm()
    for bits in (4, 8):
        recipe = QuantRecipe(method="flexround", w_bits=bits,
                             w_symmetric=True, a_bits=None, iters=200,
                             lr=5e-3, batch_size=16)
        src_params, _, _ = common.ptq(model, params, recipe)
        # compare codes of a representative weight against RTN
        w = params["layers"]["attn"]["wq"][0]
        wq = src_params["layers"]["attn"]["wq"][0]
        qcfg = QuantConfig(bits=bits, symmetric=True)
        st = flexround.init(w, qcfg)
        rtn_codes = jnp.round(w / st["s1"])
        got_codes = jnp.round(wq / st["s1"])
        shifts = jnp.abs(got_codes - rtn_codes)
        frac = float(jnp.mean(shifts > 1.0))
        mx = float(jnp.max(shifts))
        out.append(common.row(f"fig3/W{bits}/grid-shifts", 0.0,
                              f"frac_gt1={frac:.4f};max_shift={mx:.0f}"))


def bench_kernels(out: List[str]):
    """Kernel micro-bench: XLA path wall-time (CPU) + interpret-mode checks;
    derived = achieved GB/s or GFLOP/s on CPU (TPU numbers come from the
    roofline, not from this container)."""

    from repro.kernels import ref as kref

    key = jax.random.key(0)
    M, K, N = 256, 1024, 1024
    w = jax.random.normal(key, (K, N), jnp.float32) * 0.1
    s2 = jnp.ones((K, N), jnp.float32)
    s1 = jnp.full((1, N), 0.01, jnp.float32)
    zero = jnp.zeros((1, N), jnp.float32)
    f = jax.jit(lambda *a: kref.flexround_quant_ref(*a, 0, 15))
    us, _ = common.timed(f, w, s1, s2, s1, zero)
    gbs = (4 * K * N * 4) / (us * 1e-6) / 1e9
    out.append(common.row("kernels/flexround_quant_xla", us, f"GBps={gbs:.1f}"))

    aq = jax.random.randint(key, (M, K), -128, 128, jnp.int8)
    bq = jax.random.randint(key, (K, N), -128, 128, jnp.int8)
    f = jax.jit(lambda a, b: kref.qmatmul_int8_ref(
        a, b, jnp.float32(0.05), jnp.float32(2.0), jnp.full((1, N), 0.01)))
    us, _ = common.timed(f, aq, bq)
    gf = 2 * M * K * N / (us * 1e-6) / 1e9
    out.append(common.row("kernels/qmatmul_int8_xla", us, f"GFLOPs={gf:.1f}"))

    x = jax.random.normal(key, (M, K), jnp.float32)
    codes = jax.random.randint(key, (K // 2, N), 0, 256).astype(jnp.uint8)
    f = jax.jit(lambda x, c: kref.dequant_matmul_w4_ref(
        x, c, jnp.full((1, N), 0.01), jnp.full((1, N), 7.0)))
    us, _ = common.timed(f, x, codes)
    gf = 2 * M * K * N / (us * 1e-6) / 1e9
    out.append(common.row("kernels/dequant_matmul_w4_xla", us,
                          f"GFLOPs={gf:.1f}"))


def bench_token_throughput(out: List[str]):
    """Quantized serving micro-bench: tokens/s decode on the bench LM for
    bf16 vs int8 vs int4 weights (QTensor deploy path). (Named so that
    ``--only serve`` selects ``bench_serve``, the engine benchmark, not
    this uniform-batch row set — the row names are unchanged.)"""
    model, params = common.get_trained_lm()
    B, S = 8, 64
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                common.BENCH_CFG.vocab)

    def run(params_v, tag):
        us = common.timed_decode(model, params_v, QuantCtx(mode="deploy"),
                                 tokens, reps=8)
        out.append(common.row(f"serving/decode/{tag}", us,
                              f"tok_per_s={B / (us * 1e-6):.0f}"))

    run(params, "bf16")
    for bits, tag in ((8, "int8"), (4, "int4")):
        recipe = QuantRecipe(method="flexround", w_bits=bits, a_bits=None,
                             w_granularity="per_channel", iters=60, lr=3e-3,
                             batch_size=16)
        qparams, _, _ = common.ptq(model, params, recipe, as_qtensor=True)
        run(qparams, tag)


def bench_decode(out: List[str]):
    """Decode serving benchmark (kernel-backed deploy path): us_per_call and
    effective weight-bytes-moved per decode step for fp16 vs W8 vs W4.

    Every QTensor matmul dispatches through ``kernels/ops.qtensor_matmul``
    under ``backend="auto"`` (compiled Pallas on TPU; XLA ref path on the CI
    CPU, where the win shows as bytes while the TPU trajectory comes from the
    roofline). RTN export keeps the benchmark fast — it measures serving
    throughput, not reconstruction quality.
    """
    from repro.core.qtensor import tree_weight_bytes

    model, params = common.get_trained_lm()
    B, S, reps = 8, 64, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                common.BENCH_CFG.vocab)

    def run(params_v, tag):
        us = common.timed_decode(
            model, params_v, QuantCtx(mode="deploy", backend="auto"),
            tokens, reps=reps)
        wbytes = tree_weight_bytes(params_v)
        out.append(common.row(
            f"decode/{tag}", us,
            f"weight_MiB_per_step={wbytes / 2**20:.3f};"
            f"tok_per_s={B / (us * 1e-6):.0f}"))

    run(params, "fp16")
    for bits, tag in ((8, "w8"), (4, "w4")):
        recipe = QuantRecipe(method="rtn", w_bits=bits, a_bits=None,
                             w_granularity="per_channel", iters=1,
                             batch_size=16)
        qparams, _, _ = common.ptq(model, params, recipe, as_qtensor=True)
        run(qparams, tag)


def bench_recon(out: List[str]):
    """Reconstruction-throughput benchmark (the PTQ hot path itself).

    Two model scales on the scan-fused engine (the legacy per-step loop is
    gone; its trajectories are pinned as fixtures in tests):

      recon/{w4,mixed}/scan   the smoke LM (compute-bound on the CPU runner;
                              TPU wall-clock trajectories come from compiled
                              runs)
      recon/chain-L{2,6}/scan identical-structure MLP chains, the dispatch-
                              bound regime: compile_count must stay flat
                              from L2 to L6 (the compile-once cache)
      recon/sharded/scan      the L6 chain under a data-parallel mesh
                              (calibration streams sharded over the data
                              axes, states replicated): compile_count must
                              equal the unsharded L6 row, and steps_per_s is
                              the distributed-calibration throughput signal.
                              Runs the 2x4 debug mesh when 8 devices are
                              visible (the recon-sharded-smoke CI job forces
                              them on the host platform), else a mesh over
                              every available device — the derived dp/
                              devices fields say which

    derived columns:
      steps_per_s      median per-block loop throughput (steady state; the
                       one-time compile lands in the first block)
      agg_steps_per_s  total optimization steps / total loop seconds,
                       compile included (what a single PTQ run experiences)
      compile_count    actual XLA trace count across step/teacher/student/
                       recon_error/schedule/probe
      sec_per_block    wall-clock of the full PTQ divided by block count
    """
    import statistics

    from repro.core import reconstruct as rec
    from repro.core.reconstruct import quantize_blocks

    def derived(reports, wall, n_blocks):
        st = rec.engine_stats()
        steps = sum(r.iters for r in reports)
        loop = sum(r.iters / max(r.steps_per_s, 1e-9) for r in reports)
        med = statistics.median(r.steps_per_s for r in reports)
        return (f"steps_per_s={med:.1f};"
                f"agg_steps_per_s={steps / max(loop, 1e-9):.1f};"
                f"compile_count={st.compile_count};"
                f"sec_per_block={wall / n_blocks:.3f}")

    model, params = common.get_trained_lm()
    w4 = dict(method="flexround", w_bits=4, w_symmetric=True, a_bits=None,
              w_granularity="per_channel", iters=80, lr=3e-3, batch_size=16)
    recipes = {
        "w4": QuantRecipe(**w4),
        "mixed": QuantRecipe(**{**w4, "a_bits": 8, "setting": "qdrop"},
                             rules=("layers.0.*:w_bits=8",
                                    "layers.3.*:w_bits=8,a_bits=none")),
    }
    for tag, recipe in recipes.items():
        rec.reset_engine_stats()
        rec.clear_engine_cache()
        t0 = time.perf_counter()
        _, _, reports = common.ptq(model, params, recipe)
        wall = time.perf_counter() - t0
        out.append(common.row(f"recon/{tag}/scan", wall * 1e6,
                              derived(reports, wall, len(reports))))

    # dispatch-bound multi-block chains: compile_count flat L2 vs L6
    x = jax.random.normal(jax.random.key(11), (64, 32), jnp.float32)
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=100, lr=3e-3, batch_size=16)
    for n_blocks in (2, 6):
        blocks = common.make_block_chain(n_blocks)
        rec.reset_engine_stats()
        rec.clear_engine_cache()
        t0 = time.perf_counter()
        _, _, reports = quantize_blocks(blocks, recipe, x)
        wall = time.perf_counter() - t0
        out.append(common.row(f"recon/chain-L{n_blocks}/scan", wall * 1e6,
                              derived(reports, wall, n_blocks)))

    # data-parallel calibration: the L6 chain again, streams sharded over
    # the mesh's data axes (ROADMAP §Distributed calibration)
    from repro.launch.mesh import (axis_size, dp_axes, make_debug_mesh,
                                   make_flat_mesh)
    n_dev = jax.device_count()
    mesh = make_debug_mesh() if n_dev >= 8 else make_flat_mesh(n_dev)
    blocks = common.make_block_chain(6)
    rec.reset_engine_stats()
    rec.clear_engine_cache()
    t0 = time.perf_counter()
    _, _, reports = quantize_blocks(blocks, recipe, x, mesh=mesh)
    wall = time.perf_counter() - t0
    out.append(common.row(
        "recon/sharded/scan", wall * 1e6,
        derived(reports, wall, 6)
        + f";devices={n_dev};dp={axis_size(mesh, dp_axes(mesh))}"))


def bench_serve(out: List[str]):
    """Continuous-batching serve-engine benchmark (repro.serve).

    Rows (always emitted — a family the engine cannot serve degrades to a
    ``skipped=<reason>`` row, mirroring the recon/sharded fallback
    contract):

      serve/decode/int8-kv   sustained decode at full slot occupancy with
                             the int8 KV cache (the serving default)
      serve/decode/bf16-kv   same loop with the bf16 KV cache — the A/B
                             for hbm_per_slot_MiB (int8 must be strictly
                             below; pinned by tests/test_serve.py)
      serve/prefill/b{N}     bucketed AOT prefill latency per bucket
                             actually exercised by the request mix —
                             us_per_call is the histogram p50 over every
                             call (count/p95 in derived), not the last call
      serve/requests/int8-kv per-request lifecycle percentiles from a
                             scheduler run under the live telemetry sink:
                             us_per_call is TTFT p50, derived carries TTFT
                             p95 + queue-wait p50/p95 — read back from the
                             ``kind="request"`` JSONL events, exactly what
                             a production sink would aggregate

    derived columns:
      tokens_per_s      slots x steps / wall — sustained full-occupancy
                        decode throughput (us_per_call is per step)
      hbm_per_slot_MiB  bytes of KV state one slot pins, from the live
                        cache pytree
      compile_count     executables built at engine init (buckets + 1
                        decode); flat in occupancy and request count —
                        quantlint's no_retrace pins it in tier-1
      slots             decode slot capacity of the run
    """
    import numpy as np

    from repro.obs.serve_metrics import percentiles_from_events
    from repro.obs.sink import ListSink
    from repro.obs.telemetry import TELEMETRY
    from repro.serve import KVQuantUnsupported, Request, Scheduler
    from repro.serve.engine import EngineConfig, ServeEngine

    model, params = common.get_trained_lm()
    recipe = QuantRecipe(method="rtn", w_bits=4, a_bits=None,
                         w_granularity="per_channel", iters=1, batch_size=16)
    qparams, _, _ = common.ptq(model, params, recipe, as_qtensor=True)
    ctx = QuantCtx(mode="deploy", backend="auto")
    slots, max_len, max_new, steps = 4, 64, 24, 16
    rng = np.random.default_rng(0)

    for tag, kv_quant, dtype in (("int8-kv", True, None),
                                 ("bf16-kv", False, jnp.bfloat16)):
        try:
            eng = ServeEngine(model, qparams, ctx,
                              EngineConfig(slots=slots, max_len=max_len,
                                           prefill_group=2,
                                           kv_quant=kv_quant, dtype=dtype))
        except KVQuantUnsupported as e:
            out.append(common.row(f"serve/decode/{tag}", 0.0,
                                  f"skipped={e.reason}"))
            continue
        rid = 0
        lens = (5, 6, 20, 24)  # two groups -> two buckets (8 and 32)
        while eng.free_slots():  # fill every slot (mixed prompt lengths)
            grp = min(len(eng.free_slots()), eng.cfg.prefill_group)
            eng.admit([(rid + j,
                        rng.integers(0, common.BENCH_CFG.vocab,
                                     size=lens[(rid + j) % len(lens)],
                                     ).astype(np.int32),
                        max_new) for j in range(grp)])
            rid += grp
        eng.step()  # warm (executable is AOT, this warms allocator/caches)
        t0 = time.perf_counter()
        for _ in range(steps):
            eng.step()
        dt = time.perf_counter() - t0
        st = eng.stats()
        # MiB derived from the engine's byte accessor (hbm_per_slot_bytes),
        # the same value quantlint's QL403 cross-checks statically from the
        # decode jaxpr — the column can never drift from what the graph moves
        out.append(common.row(
            f"serve/decode/{tag}", dt / steps * 1e6,
            f"tokens_per_s={slots * steps / dt:.0f};"
            f"hbm_per_slot_MiB={st['hbm_per_slot_bytes'] / 2**20:.4f};"
            f"compile_count={st['compile_count']};slots={slots}"))
        if kv_quant:
            for b, s in sorted(st["prefill_us"].items()):
                out.append(common.row(
                    f"serve/prefill/b{b}", s["p50"],
                    f"bucket={b};group={eng.cfg.prefill_group};"
                    f"count={s['count']:.0f};p95={s['p95']:.1f}"))
            # per-request TTFT / queue-wait percentiles: drain the direct
            # admits, then drive a scheduler run (more requests than slots,
            # so queue wait is non-trivial) under a live telemetry sink and
            # fold the kind="request" JSONL events back into percentiles
            while eng.active:
                eng.step()
            eng.drain_finished()
            n_req = 10
            sink = ListSink()
            with TELEMETRY.enabled_scope(sink=sink):
                with Scheduler(eng) as sched:
                    sched.run([
                        Request(rid=1000 + i,
                                tokens=rng.integers(
                                    0, common.BENCH_CFG.vocab,
                                    size=lens[i % len(lens)],
                                    ).astype(np.int32),
                                max_new=8)
                        for i in range(n_req)])
                    detok_errors = sched.metrics.detok_errors
            ttft = percentiles_from_events(sink.records, "request",
                                           "ttft_us")
            qw = percentiles_from_events(sink.records, "request",
                                         "queue_wait_us")
            out.append(common.row(
                f"serve/requests/{tag}", ttft["p50"],
                f"requests={n_req};slots={slots};"
                f"ttft_p95={ttft['p95']:.1f};"
                f"queue_wait_p50={qw['p50']:.1f};"
                f"queue_wait_p95={qw['p95']:.1f};"
                f"detok_errors={detok_errors}"))


def bench_alloc(out: List[str]):
    """Automatic bit-allocation benchmark (repro.allocate).

    Rows:
      alloc/probe              probe-pass cost on the smoke LM:
                               probe_steps (one forward per site x candidate
                               bits), steps_per_s, compile_count (probe +
                               teacher traces — O(distinct apply_keys), so
                               it stays flat as layers are added)
      alloc/uniform-w4         uniform W4 PTQ baseline: aggregate recon MSE
                               (sum of per-block err_after) + quantized-site
                               bytes + effective tree MiB
      alloc/auto-4.5           auto allocation at avg_bits=4.5 (the extra
                               half bit buys 8-bit grids at the most
                               sensitive sites)
      alloc/auto-matched-bytes auto allocation under a weight_bytes budget
                               set to uniform W4's quantized-site bytes —
                               same serving bytes, sensitivity-shaped
    """
    from repro.allocate import (AllocationReport, Budget, probe_blocks,
                                solve_allocation)
    from repro.core import reconstruct as rec
    from repro.core.qtensor import tree_weight_bytes
    from repro.core.reconstruct import quantize_blocks
    from repro.data import CalibrationSet, SyntheticTokens

    model, params = common.get_trained_lm()
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, w_granularity="per_channel", iters=80,
                         lr=3e-3, batch_size=16)
    src = SyntheticTokens(vocab=common.BENCH_CFG.vocab, seq_len=common.SEQ,
                          seed=0)
    cal = CalibrationSet.build(src, 64)
    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)

    rec.reset_engine_stats()
    rec.clear_engine_cache()
    probe = probe_blocks(blocks, recipe, x0)
    out.append(common.row(
        "alloc/probe", probe.seconds * 1e6,
        f"probe_steps={probe.steps};steps_per_s={probe.steps_per_s:.1f};"
        f"compile_count={probe.compile_count}"))

    w4_site_bytes = sum(per[4].cost_bytes for per in probe.scores.values())
    variants = {
        "uniform-w4": (recipe, None),
        "auto-4.5": (None, Budget("avg_bits", 4.5)),
        "auto-matched-bytes": (None, Budget("weight_bytes",
                                            float(w4_site_bytes))),
    }
    for tag, (r, budget) in variants.items():
        if r is None:
            alloc = solve_allocation(probe, budget)
            report = AllocationReport.build(probe, alloc)
            r = recipe.with_rules(*report.rules())
        t0 = time.perf_counter()
        finalized, _, reports = quantize_blocks(blocks, r, x0,
                                                as_qtensor=True)
        wall = time.perf_counter() - t0
        mse = sum(rep.err_after for rep in reports)
        wbytes = tree_weight_bytes(assemble(finalized))
        site_bytes = sum(per[r.resolve(s).weight.bits].cost_bytes
                         for s, per in probe.scores.items())
        avg_bits = (sum(per[r.resolve(s).weight.bits].numel
                        * r.resolve(s).weight.bits
                        for s, per in probe.scores.items())
                    / sum(per[4].numel for per in probe.scores.values()))
        out.append(common.row(
            f"alloc/{tag}", wall * 1e6,
            f"recon_mse={mse:.4e};avg_bits={avg_bits:.3f};"
            f"site_bytes={site_bytes};"
            f"weight_MiB={wbytes / 2**20:.3f}"))


ALL_TABLES = [table1_ablation, table2_weights_only, table3_w_a,
              table5_lm_w8a8, table7_llm_blockwise, fig3_grid_shifts,
              bench_kernels, bench_token_throughput, bench_decode,
              bench_recon, bench_serve, bench_alloc]
