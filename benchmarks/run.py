"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only tableN]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on table function names")
    args = ap.parse_args()

    from benchmarks.tables import ALL_TABLES

    rows = ["name,us_per_call,derived"]
    failures = 0
    for fn in ALL_TABLES:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.perf_counter()
        try:
            fn(rows)
            print(f"# {fn.__name__} done in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {fn.__name__} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print("\n".join(rows), flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
