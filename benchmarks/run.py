"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only tableN] [--json out.json]

``--json`` additionally writes the rows as a list of records (the ``derived``
key=value pairs parsed into typed fields) — CI jobs upload these to build
perf trajectories.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def rows_to_records(rows):
    """``name,us_per_call,derived`` CSV lines -> JSON-able dicts."""
    records = []
    for line in rows:
        name, us, derived = line.split(",", 2)
        rec = {"name": name, "us_per_call": float(us)}
        for pair in filter(None, derived.split(";")):
            k, eq, v = pair.partition("=")
            if not eq:
                continue
            try:
                rec[k] = float(v)
            except ValueError:
                rec[k] = v
        records.append(rec)
    return records


def stamp_records(records):
    """Stamp every bench record with the run manifest (git sha + schema
    version) so a perf trajectory is attributable to the commit and JSONL
    schema that produced it. Validated by ``repro.obs.sink --check-bench``."""
    from repro.obs.sink import current_manifest
    brief = current_manifest().brief()
    for rec in records:
        rec["manifest"] = dict(brief)
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on table function names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON records to PATH")
    args = ap.parse_args()

    from benchmarks.tables import ALL_TABLES

    rows = ["name,us_per_call,derived"]
    failures = 0
    for fn in ALL_TABLES:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.perf_counter()
        try:
            fn(rows)
            print(f"# {fn.__name__} done in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {fn.__name__} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print("\n".join(rows), flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stamp_records(rows_to_records(rows[1:])), f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
