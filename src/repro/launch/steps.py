"""train_step / prefill_step / serve_step builders + cell programs.

A CellProgram bundles everything the dry-run (and a real launch) needs for
one (arch x shape x mesh) combination: the step function, ShapeDtypeStruct
arguments, and in/out shardings. Weight modes for serving:

  bf16   full-precision serving (roofline baseline)
  int8   FlexRound-quantized weight-only (paper-faithful LLM recipe)
  int4   packed-int4 weight-only (beyond-paper; see EXPERIMENTS.md §Perf)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.core.context import QuantCtx
from repro.launch import sharding as shd
from repro.launch import specs as sp
from repro.models import build_model
from repro.optim.adam import AdamConfig, adam_init, adam_update

TRAIN_OPT = {
    "fsdp": AdamConfig(lr=3e-4, weight_decay=0.1, grad_clip=1.0,
                       moment_dtype="bfloat16"),
    "tp": AdamConfig(lr=3e-4, weight_decay=0.1, grad_clip=1.0),
    "dp": AdamConfig(lr=3e-4, weight_decay=0.1, grad_clip=1.0),
}


@dataclasses.dataclass
class CellProgram:
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda l: isinstance(l, P))


# ------------------------------------------------------------------ train
def make_train_step(model, cfg, opt_cfg: AdamConfig, microbatch: int = 1):
    """microbatch > 1: gradient accumulation over a lax.scan of microbatches
    — cuts peak activation memory ~microbatch-x at the same math (standard
    1000-node practice; see EXPERIMENTS.md §Perf train iteration)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, QuantCtx(mode="fp"))
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        if microbatch == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)

            def micro(gacc, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gacc, g)
                return gacc, l

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, gacc0, split)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = jnp.mean(losses)
            metrics = {}
        new_params, new_opt, gnorm = adam_update(grads, state["opt"],
                                                 params, opt_cfg)
        out = {"params": new_params, "opt": new_opt,
               "step": state["step"] + 1}
        return out, {"loss": loss, "gnorm": gnorm, **metrics}

    return train_step


def train_cell(cfg, shape: ShapeSpec, mesh, mode: Optional[str] = None,
               microbatch: int = 1) -> CellProgram:
    model = build_model(cfg)
    mode = mode or shd.ARCH_MODE.get(cfg.name, "tp")
    opt_cfg = TRAIN_OPT[mode]
    pshapes = sp.param_shapes(model, cfg)
    oshapes = jax.eval_shape(lambda p: adam_init(p, opt_cfg), pshapes)
    state_shapes = {"params": pshapes, "opt": oshapes,
                    "step": sp.sds((), jnp.int32)}
    bshapes = sp.batch_shapes(cfg, shape)

    pspec = shd.param_spec_tree(pshapes, cfg, mesh, mode)
    # moments mirror params: same shapes => same specs
    mom_spec = jax.tree.map(lambda s: {"m": s, "v": s}, pspec,
                            is_leaf=lambda l: isinstance(l, P))
    state_spec = {"params": pspec, "opt": {"mu": mom_spec, "count": P()},
                  "step": P()}
    bspec = shd.batch_spec_tree(bshapes, cfg, mesh)

    fn = make_train_step(model, cfg, opt_cfg, microbatch=microbatch)
    return CellProgram(
        name=f"{cfg.name}:{shape.name}" + (
            f":mb{microbatch}" if microbatch > 1 else ""),
        fn=fn,
        args=(state_shapes, bshapes),
        in_shardings=(_named(mesh, state_spec), _named(mesh, bspec)),
        out_shardings=(_named(mesh, state_spec), None),
        donate_argnums=(0,),
    )


# ------------------------------------------------------------------ serve
def make_prefill_step(model, cfg):
    def prefill_step(params, tokens, cache, extra=None):
        ctx = QuantCtx(mode="deploy", backend="auto")
        if cfg.family == "encdec":
            h, cache = model.prefill(params, tokens, extra, cache, ctx)
        elif cfg.family == "vlm":
            h, cache = model.prefill(params, tokens, cache, ctx,
                                     extra_embeds=extra)
        else:
            h, cache = model.prefill(params, tokens, cache, ctx)
        logits = h @ _head(model, params, cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def _head(model, params, cfg):
    if hasattr(model, "lm_head"):
        w = model.lm_head(params)
    else:
        w = params["lm_head"]
    return w.astype(jnp.dtype(cfg.dtype)) * cfg.logit_mult


def make_serve_step(model, cfg):
    """One decode step: insert token, attend against cache, next token."""

    def serve_step(params, token, cache, pos):
        ctx = QuantCtx(mode="deploy", backend="auto")
        logits, cache = model.decode_step(params, token, cache, pos, ctx)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def serve_cell(cfg, shape: ShapeSpec, mesh, weights: str = "int8",
               mode: Optional[str] = None, kv: str = "bf16") -> CellProgram:
    model = build_model(cfg)
    mode = mode or shd.serve_mode(cfg.name)
    pshapes = sp.param_shapes(model, cfg)
    if weights in ("int8", "int4"):
        pshapes = sp.quantize_param_shapes(pshapes, cfg,
                                           bits=8 if weights == "int8" else 4)
    cshapes = sp.cache_shapes(model, cfg, shape, kv=kv)
    pspec = shd.param_spec_tree(pshapes, cfg, mesh, mode)
    cspec = shd.cache_spec_tree(cshapes, cfg, mesh)
    B = shape.global_batch
    dp = shd.dp_axes(mesh)
    b_ax = dp if B % shd.axis_size(mesh, dp) == 0 else (
        ("data",) if B % mesh.shape["data"] == 0 else None)

    if shape.kind == "decode":
        token = sp.sds((B, 1), jnp.int32)
        pos = sp.sds((), jnp.int32)
        fn = make_serve_step(model, cfg)
        return CellProgram(
            name=f"{cfg.name}:{shape.name}:{weights}",
            fn=fn,
            args=(pshapes, token, cshapes, pos),
            in_shardings=(_named(mesh, pspec),
                          NamedSharding(mesh, P(b_ax, None)),
                          _named(mesh, cspec), NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, P(b_ax, None)),
                           _named(mesh, cspec)),
            donate_argnums=(2,),
        )

    # prefill
    bshapes = sp.batch_shapes(cfg, shape)
    tokens = bshapes["tokens"]
    tok_spec = NamedSharding(mesh, P(b_ax, None))
    extra = None
    extra_spec = None
    if cfg.family == "encdec":
        extra = bshapes["frames"]
        extra_spec = NamedSharding(mesh, P(b_ax, None, None))
    elif cfg.family == "vlm":
        extra = bshapes["patch_embeds"]
        extra_spec = NamedSharding(mesh, P(b_ax, None, None))
    fn = make_prefill_step(model, cfg)
    args = (pshapes, tokens, cshapes) + ((extra,) if extra is not None else ())
    in_sh = (_named(mesh, pspec), tok_spec, _named(mesh, cspec)) + (
        (extra_spec,) if extra_spec is not None else ())
    return CellProgram(
        name=f"{cfg.name}:{shape.name}:{weights}",
        fn=fn,
        args=args,
        in_shardings=in_sh,
        out_shardings=(tok_spec, _named(mesh, cspec)),
        donate_argnums=(2,),
    )


def build_cell(cfg, shape: ShapeSpec, mesh, weights: str = "int8",
               mode: Optional[str] = None, microbatch: int = 1,
               kv: str = "bf16") -> CellProgram:
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh, mode, microbatch=microbatch)
    return serve_cell(cfg, shape, mesh, weights, mode, kv=kv)
