import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module:  PYTHONPATH=src python -m repro.launch.dryrun
  --arch <id|all> --shape <id|all> [--multi-pod/--single-pod/--both]
  [--weights int8] [--out results.json]

The two XLA_FLAGS lines above execute before ANY other import (jax locks the
device count on first init), giving 512 virtual host devices for the
production meshes. Do NOT set this flag globally — tests/benchmarks must see
one device.

For each cell this prints/records compiled.memory_analysis() (fits-per-chip
evidence), compiled.cost_analysis() (FLOPs/bytes for §Roofline), and the
collective-byte summary parsed from the compiled HLO.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPE_IDS, cell_applicable, get_config, get_shape  # noqa: E402
from repro.obs.telemetry import Stopwatch  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402
from repro.roofline.analysis import analyze_compiled  # noqa: E402


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             weights: str = "int8", verbose: bool = True,
             mode: str = None, microbatch: int = 1, kv: str = "bf16",
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}|{shape_name}|{mesh_name}|{weights}" + (
        f"|{tag}" if tag else "")
    if not ok:
        return {"cell": cell_id, "status": "skipped", "reason": why}
    sw = Stopwatch()
    try:
        from repro.models.common import ambient_mesh
        mesh = make_production_mesh(multi_pod=multi_pod)
        prog = build_cell(cfg, shape, mesh, weights=weights, mode=mode,
                          microbatch=microbatch, kv=kv)
        with mesh, ambient_mesh(mesh):
            lowered = jax.jit(
                prog.fn,
                in_shardings=prog.in_shardings,
                out_shardings=prog.out_shardings,
                donate_argnums=prog.donate_argnums,
            ).lower(*prog.args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        result = {
            "cell": cell_id,
            "status": "ok",
            "compile_s": round(sw.elapsed_s(), 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes_per_device": (mem.argument_size_in_bytes
                                          + mem.output_size_in_bytes
                                          + mem.temp_size_in_bytes
                                          - mem.alias_size_in_bytes),
            },
            "analysis": analyze_compiled(compiled, cfg, shape, mesh,
                                         weights=weights, mode=mode, kv=kv),
        }
        if verbose:
            a = result["analysis"]
            print(f"[OK ] {cell_id}  compile={result['compile_s']}s  "
                  f"peak/dev={result['memory']['peak_bytes_per_device']/2**30:.2f}GiB  "
                  f"compute={a['compute_s']:.3e}s memory={a['memory_s']:.3e}s "
                  f"collective={a['collective_s']:.3e}s -> {a['bottleneck']}",
                  flush=True)
        return result
    except Exception as e:  # noqa: BLE001 - record and continue
        if verbose:
            print(f"[ERR] {cell_id}: {e}", flush=True)
            traceback.print_exc()
        return {"cell": cell_id, "status": "error", "error": str(e),
                "compile_s": round(sw.elapsed_s(), 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--weights", default="int8",
                    choices=["bf16", "int8", "int4"])
    ap.add_argument("--mode", default=None,
                    choices=[None, "dp", "tp", "fsdp"],
                    help="override the per-arch parallelism mode")
    ap.add_argument("--kv", default="bf16", choices=["bf16", "int8"],
                    help="KV-cache precision for serve cells")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--tag", default="", help="suffix for the cell id")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = SHAPE_IDS if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {r["cell"] for r in results if r.get("status") == "ok"}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                cid = f"{arch}|{shape}|{mesh_name}|{args.weights}" + (
                    f"|{args.tag}" if args.tag else "")
                if cid in done:
                    print(f"[SKIP cached] {cid}", flush=True)
                    continue
                results.append(run_cell(arch, shape, multi_pod=mp,
                                        weights=args.weights, mode=args.mode,
                                        microbatch=args.microbatch,
                                        kv=args.kv, tag=args.tag))
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped (per assignment), "
          f"{n_err} errors -> {args.out}", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
