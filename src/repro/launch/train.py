"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --smoke                      # CPU-runnable smoke
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
        --shape train_4k                         # on a real pod slice

On real hardware this process runs per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set); in this container it runs single-host.
Fault tolerance: rolling atomic checkpoints + deterministic counter-based
data; restart resumes exactly. Elastic: checkpoints are mesh-agnostic.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_shape, get_smoke_config
from repro.data import SyntheticTokens
from repro.launch import sharding as shd
from repro.launch.steps import TRAIN_OPT, make_train_step
from repro.models import build_model
from repro.obs.telemetry import Stopwatch
from repro.optim.adam import adam_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny batch (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = get_shape(args.shape)
    B, S = (8, 64) if args.smoke else (shape.global_batch, shape.seq_len)

    model = build_model(cfg)
    mode = shd.ARCH_MODE.get(cfg.name, "tp")
    opt_cfg = TRAIN_OPT[mode]
    src = SyntheticTokens(vocab=cfg.vocab, seq_len=S, seed=0)

    mgr = CheckpointManager(args.ckpt_dir or f"/tmp/ckpt_{cfg.name}", keep=3)
    state, meta = mgr.restore()
    if state is None:
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adam_init(params, opt_cfg),
                 "step": jnp.int32(0)}
        start = 0
    else:
        start = int(meta["step"])
        print(f"resumed from step {start}", flush=True)

    step_fn = jax.jit(make_train_step(model, cfg, opt_cfg),
                      donate_argnums=(0,))
    sw = Stopwatch()
    for step in range(start, args.steps):
        batch = dict(src.batch(step, B))
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.key(step), (B, S, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.key(step), (B, cfg.n_patches, cfg.d_model),
                jnp.float32)
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"({sw.elapsed_s():.1f}s)", flush=True)
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state)
    mgr.save(args.steps, state)
    print("training done", flush=True)


if __name__ == "__main__":
    main()
