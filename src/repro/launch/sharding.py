"""Per-architecture sharding rules (DP / FSDP / TP / EP / SP).

Specs are derived from *shape trees* (jax.eval_shape) so no memory is touched.
Every rule validates divisibility against the actual mesh — jit input
shardings reject uneven dims — and degrades an axis to replication when a dim
doesn't divide (e.g. granite's 49155 vocab, mamba's fused projection).

Parallelism modes per arch (see DESIGN.md §4):
  dp    params replicated, batch over data axes (small models)
  tp    tensor parallel over 'model' (2-10B)
  fsdp  tp + parameters/optimizer sharded over data axes too (>=14B)
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes

ARCH_MODE = {
    "qwen2.5-14b": "fsdp",
    "smollm-135m": "dp",
    "granite-3-2b": "tp",
    "olmo-1b": "tp",
    "recurrentgemma-2b": "tp",
    "llama4-scout-17b-a16e": "fsdp",
    "deepseek-v3-671b": "fsdp",
    "mamba2-130m": "dp",
    "whisper-medium": "tp",
    "phi-3-vision-4.2b": "tp",
}

# serving prefers TP everywhere: replicated weights multiply per-chip HBM
# weight traffic by n_dev (§Perf smollm iteration: 1.43x better memory term),
# and FSDP-sharded weights would be re-gathered every decode step (§Perf qwen
# iteration). MoE experts keep full EP via the expert rule; deepseek's
# non-expert weights fit on the model axis (0.8 GiB/chip int8).
SERVE_MODE = {
    "smollm-135m": "tp",
    "mamba2-130m": "tp",
    "qwen2.5-14b": "tp",
    "llama4-scout-17b-a16e": "tp",
    "deepseek-v3-671b": "tp",
}


def serve_mode(name: str) -> str:
    return SERVE_MODE.get(name, ARCH_MODE.get(name, "tp"))

_ROW_PARALLEL = re.compile(r"(wo|w_down|out_proj)$")
_REPLICATED = re.compile(
    r"(router|conv_w|conv_b|a_log|dt_bias|d_skip|lam|b_a|b_i|scale|bias|"
    r"bq|bk|bv|bo|b_up|b_down)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return ".".join(parts)


def _div(dim: int, mesh, axes) -> bool:
    return axes is not None and dim % axis_size(mesh, axes) == 0


def _matrix_spec(shape, mesh, mode: str, name: str, lead: int) -> P:
    """Spec for a (lead..., d_in, d_out) weight matrix."""
    dp = dp_axes(mesh)
    d_in, d_out = shape[-2], shape[-1]
    row = bool(_ROW_PARALLEL.search(name))
    specs = [None] * len(shape)
    tp_dim = len(shape) - 2 if row else len(shape) - 1
    fs_dim = len(shape) - 1 if row else len(shape) - 2
    if _div(shape[tp_dim], mesh, ("model",)):
        specs[tp_dim] = "model"
    elif _div(shape[fs_dim], mesh, ("model",)):
        # fall back: shard the other dim over model
        specs[fs_dim] = "model"
        fs_dim = tp_dim
    if mode == "fsdp" and specs[fs_dim] is None and _div(shape[fs_dim], mesh, dp):
        specs[fs_dim] = dp
    return P(*specs)


def _expert_spec(shape, mesh, mode: str) -> P:
    """(L, E, d_in, d_out) stacked expert weights.

    Full EP when E divides data*model (deepseek: 256 experts over 256 chips,
    one expert per chip => zero weight gathers; tokens move via all-to-all
    instead — §Perf deepseek iteration 2). Otherwise EP over model (+FSDP
    sharding of d_in over the data axes)."""
    dp = dp_axes(mesh)
    specs = [None] * len(shape)
    e_dim = len(shape) - 3
    if _div(shape[e_dim], mesh, ("data", "model")):
        specs[e_dim] = ("data", "model")
        return P(*specs)
    if _div(shape[e_dim], mesh, ("model",)):
        specs[e_dim] = "model"
    if mode == "fsdp" and _div(shape[-2], mesh, dp):
        specs[-2] = dp
    return P(*specs)


def _embed_spec(shape, mesh, mode: str, transposed: bool) -> P:
    """embed (V, D) / lm_head (D, V): vocab-parallel when divisible."""
    dp = dp_axes(mesh)
    v_dim, d_dim = (1, 0) if transposed else (0, 1)
    specs = [None, None]
    if _div(shape[v_dim], mesh, ("model",)):
        specs[v_dim] = "model"
        if mode == "fsdp" and _div(shape[d_dim], mesh, dp):
            specs[d_dim] = dp
    elif _div(shape[d_dim], mesh, ("model",)):
        specs[d_dim] = "model"  # odd vocab (granite/mamba/whisper)
    return P(*specs)


def param_spec_tree(shapes: Any, cfg, mesh, mode: Optional[str] = None) -> Any:
    """PartitionSpec tree matching a params shape tree."""
    mode = mode or ARCH_MODE.get(cfg.name, "tp")

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if mode == "dp" or len(shape) <= 1:
            return P(*([None] * len(shape)))
        short = name.rsplit(".", 1)[-1]
        if _REPLICATED.search(short):
            return P(*([None] * len(shape)))
        if short == "embed":
            return _embed_spec(shape, mesh, mode, transposed=False)
        if short == "lm_head":
            return _embed_spec(shape, mesh, mode, transposed=True)
        if ".experts." in f".{name}." and len(shape) in (3, 4):
            return _expert_spec(shape, mesh, mode)  # (L,)E,d_in,d_out
        if len(shape) >= 2:
            return _matrix_spec(shape, mesh, mode, short,
                                lead=len(shape) - 2)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, shapes)


def batch_spec_tree(batch_shapes: Any, cfg, mesh) -> Any:
    dp = dp_axes(mesh)

    def rule(path, leaf):
        b = leaf.shape[0]
        lead = dp if _div(b, mesh, dp) else (
            ("data",) if _div(b, mesh, ("data",)) else None)
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_spec_tree(cache_shapes: Any, cfg, mesh) -> Any:
    """Decode caches: batch over data axes, SEQUENCE over model (SP decode —
    flash-decoding style: per-shard partial attention, XLA inserts the small
    LSE/psum collectives). Seq lens (32768/524288) always divide 16."""
    dp = dp_axes(mesh)

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        specs = [None] * len(shape)
        if name.endswith("kpos"):
            return P(*specs)
        # leading L dim for stacked caches
        b_dim = 1 if (len(shape) >= 3 and shape[0] == cfg.n_layers) else 0
        if _div(shape[b_dim], mesh, dp):
            specs[b_dim] = dp
        elif _div(shape[b_dim], mesh, ("data",)):
            specs[b_dim] = "data"
        # seq axis right after batch for kv/latent caches
        s_dim = b_dim + 1
        if (len(shape) > s_dim + 1 and
                any(t in name for t in ("k", "v", "ckv", "kr"))
                and _div(shape[s_dim], mesh, ("model",))):
            specs[s_dim] = "model"
        return P(*specs)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda l: isinstance(l, P))


# ---------------------------------------------------- calibration streams
def stream_spec(n_lead: int, mesh) -> P:
    """Spec for a calibration/activation-stream tensor (x_q / y_fp / gathered
    minibatches / per-sample loss weights): the leading sample axis is
    sharded over the data axes. Degrades to replication when the sample count
    does not divide the data-parallel size (jit input shardings reject uneven
    dims), mirroring every other rule in this module."""
    dp = dp_axes(mesh)
    return P(dp) if _div(n_lead, mesh, dp) else P()


def stream_sharding(mesh, n_lead: int) -> NamedSharding:
    """NamedSharding for a leading-sample-axis calibration tensor."""
    return NamedSharding(mesh, stream_spec(n_lead, mesh))


def replicated(mesh) -> NamedSharding:
    """Fully replicated placement (rounding/Adam/LSQ carry states, minibatch
    schedules, salts — everything the data-parallel recon loop must see
    identically on every device)."""
    return NamedSharding(mesh, P())


def opt_spec_tree(opt_shapes: Any, param_specs: Any) -> Any:
    """Adam moments mirror parameter sharding; count replicated."""
    mu = jax.tree.map(lambda ps: {"m": ps, "v": ps}, param_specs,
                      is_leaf=lambda l: isinstance(l, P))
    return {"mu": mu, "count": P()}
