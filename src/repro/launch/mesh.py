"""Production mesh definition (functions only — importing this module never
touches jax device state).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the pod axis
composes with data for DP/FSDP (batch and parameter sharding span pod*data).
"""
from __future__ import annotations

from typing import Tuple

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer jax; older releases are
    Auto-by-default, so omitting the argument is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-scale sharding tests (8 virtual devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_flat_mesh(n_devices: int):
    """All-device (n, 1) data-parallel mesh — the fallback for benchmarks /
    smoke runs on hosts that don't expose the debug mesh's 8 devices."""
    return _make_mesh((n_devices, 1), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that act as data/FSDP parallel dims."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
