"""ShapeDtypeStruct stand-ins for every (arch x shape) cell — no allocation.

Also: shape-level transformation of a params tree into its quantized-serving
form (QTensor leaves with int8 / packed-int4 codes), mirroring exactly what
core.reconstruct.finalize + assemble() produce at runtime.
"""
from __future__ import annotations

import re
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.core.qtensor import QTensor

WHISPER_CROSS_LEN = 1504  # ~30s of frames, divisible by 16

_QUANT_SITE = re.compile(
    r"(wq|wk|wv|wo|w_gate|w_up|w_down|in_proj|out_proj|wq_a|wq_b|wkv_a|"
    r"wkv_b|w_x|w_a|w_i)$")
_STACK_KEYS = ("layers", "dense_layers", "enc_layers", "dec_layers")


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _path_parts(path):
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(p.key)
        elif hasattr(p, "idx"):
            parts.append(p.idx)
    return parts


def param_shapes(model, cfg) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def quantize_param_shapes(shapes: Any, cfg, bits: int = 8) -> Any:
    """Replace quantizable weight leaves with QTensor shape-structs
    (per-channel asymmetric grid — the paper's LLM serving recipe)."""

    def rule(path, leaf):
        parts = _path_parts(path)
        short = str(parts[-1]) if parts else ""
        is_expert = "experts" in parts
        quantizable = (leaf.ndim >= 2
                       and (_QUANT_SITE.search(short) or is_expert)
                       and short not in ("embed", "lm_head", "router"))
        if not quantizable:
            return leaf
        stacked = (isinstance(parts[0], str) and parts[0] in _STACK_KEYS
                   and not any(isinstance(p, int) for p in parts))
        shape = list(leaf.shape)
        logical = tuple(shape[1:]) if stacked else tuple(shape)
        # nibble-pack along the first non-batch axis of the *logical* tensor
        # (K for linears; E-stacked experts keep per-expert addressing), the
        # layout core.qtensor.from_codes produces and the kernels consume
        batch_dims = 1 if (is_expert and len(logical) == 3) else 0
        pack_dim = (1 if stacked else 0) + batch_dims
        packed = bits <= 4 and shape[pack_dim] % 2 == 0
        cshape = list(shape)
        if packed:
            cshape[pack_dim] //= 2
        sshape = list(shape[:-2]) + [1, shape[-1]]
        return QTensor(
            codes=sds(cshape, jnp.uint8),
            scale=sds(sshape, jnp.float32),
            zero=sds(sshape, jnp.float32),
            shape=logical,
            bits=bits,
            packed=packed,
            dtype=cfg.dtype,
            pack_axis=batch_dims,
        )

    return jax.tree_util.tree_map_with_path(rule, shapes)


def batch_shapes(cfg, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    d = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32),
                "frames": sds((B, S, cfg.d_model), d)}
    if cfg.family == "vlm":
        S_text = S - cfg.n_patches
        return {"tokens": sds((B, S_text), jnp.int32),
                "labels": sds((B, S_text), jnp.int32),
                "patch_embeds": sds((B, cfg.n_patches, cfg.d_model), d)}
    return {"tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32)}


def cache_shapes(model, cfg, shape: ShapeSpec, kv: str = "bf16") -> Any:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: model.init_cache(B, S, enc_len=WHISPER_CROSS_LEN))
    if kv == "int8" and cfg.family in ("dense", "moe", "vlm") \
            and not cfg.use_mla:
        return jax.eval_shape(lambda: model.init_cache(B, S, kv_quant=True))
    return jax.eval_shape(lambda: model.init_cache(B, S))
