"""Production PTQ launcher: load a trained checkpoint, run block-wise
FlexRound (or any registered method), export integer weights.

    PYTHONPATH=src python -m repro.launch.quantize --arch smollm-135m \
        --smoke --method flexround --w-bits 8 --a-bits 8

Mixed precision via per-site rules (glob over site names, last match wins):

    ... --w-bits 4 --rule 'layers.0.*:w_bits=8' --rule 'layers.11.*:w_bits=8'

gives the standard LLM recipe (W4 body, W8 first/last layers); rules may also
override method, granularity, lr, or a_bits per site (``a_bits=none`` keeps a
site's activations fp).

Automatic mixed precision (sensitivity-guided, repro.allocate):

    ... --auto-bits 4.5                   # numel-weighted average bits
    ... --auto-bits 150000 --budget bytes # serving-bytes budget

probes every site at candidate bit-widths on the calibration set, solves the
budget and appends the emitted per-site rules to the recipe — probe, solve
and quantize in one invocation. The allocation is persisted to --resume-dir
(allocation.json) and stamped into every per-block checkpoint, so a resume
under a different allocation fails loudly.

Distributed calibration (--mesh): reconstruction runs data-parallel over the
mesh — the calibration set is built per-host from the deterministic
``SyntheticTokens.batch(step, host, n_hosts)`` shards (one simulated host per
data-parallel slice), assembled under the straggler policy, and its loss
weight is consumed by the recon objective; calibration/activation streams are
sharded over the mesh's data axes on the leading sample axis while rounding/
Adam/LSQ states stay replicated. ``--mesh debug`` needs 8 devices (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU);
``--mesh production`` expects the 16x16 pod of launch/mesh.py.

Fault tolerance: per-block PTQ checkpoints (--resume-dir) — a preempted run
resumes at the first unfinished block with identical RNG; resuming under
different rules fails loudly (per-site plans are recorded in the checkpoint).
"""
from __future__ import annotations

import argparse
import os

import jax

from repro.checkpoint import CheckpointManager, save_pytree
from repro.configs import get_config, get_smoke_config
from repro.core import QuantRecipe, method_api
from repro.core.reconstruct import (DEFAULT_CHUNK, engine_stats,
                                    quantize_blocks, reset_engine_stats,
                                    site_plans)
from repro.data import CalibrationSet, SyntheticTokens
from repro.launch.mesh import (axis_size, dp_axes, make_debug_mesh,
                               make_production_mesh)
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="flexround",
                    choices=list(method_api.available_methods()))
    ap.add_argument("--setting", default="qdrop", choices=["brecq", "qdrop"])
    ap.add_argument("--recon", default="block", choices=["block", "layer"])
    ap.add_argument("--w-bits", type=int, default=8)
    ap.add_argument("--a-bits", type=int, default=None)
    ap.add_argument("--w-granularity", default="per_channel")
    ap.add_argument("--rule", action="append", default=[],
                    metavar="GLOB:K=V[,K=V...]",
                    help="per-site override, e.g. 'layers.0.*:w_bits=8'; "
                         "repeatable, later rules win")
    ap.add_argument("--calib", type=int, default=64)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--from-ckpt", default=None,
                    help="CheckpointManager dir of a trained model")
    ap.add_argument("--resume-dir", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="deploy-mode kernel dispatch: auto = compiled "
                         "Pallas on TPU / XLA ref path elsewhere; pallas = "
                         "Pallas kernels (interpreted off-TPU); xla = "
                         "pure-jnp refs")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="after quantization, run a short deploy-mode decode "
                         "through the kernel serving path and report "
                         "us/step + weight bytes moved")
    ap.add_argument("--serve", action="store_true",
                    help="after quantization, run the continuous-batching "
                         "serve engine (bucketed AOT prefill, slot decode, "
                         "int8 KV) on a synthetic request stream and report "
                         "tokens/s, HBM/slot, compile_count")
    ap.add_argument("--serve-slots", type=int, default=4,
                    help="decode slots for --serve")
    ap.add_argument("--serve-requests", type=int, default=8,
                    help="synthetic request count for --serve")
    ap.add_argument("--serve-max-new", type=int, default=16,
                    help="tokens generated per request for --serve")
    ap.add_argument("--no-kv-quant", action="store_true",
                    help="serve with the fp KV cache instead of the int8 "
                         "default (A/B the HBM-per-slot win)")
    ap.add_argument("--analyze", action="store_true",
                    help="after quantization, run the quantlint analyzers "
                         "(repro.analysis): AST rules over src/, jaxpr "
                         "checks on the entry points, kernel-coverage "
                         "report; exit non-zero on error findings")
    ap.add_argument("--analyze-mem", action="store_true",
                    help="like --analyze, plus the memcheck layer (QL4xx): "
                         "jaxpr liveness vs the per-entry HBM-budget "
                         "contracts over the serve/deploy entries")
    ap.add_argument("--auto-bits", type=float, default=None, metavar="VALUE",
                    help="automatic mixed precision: probe per-site "
                         "sensitivity and allocate bit-widths to meet this "
                         "budget (interpreted per --budget); emitted rules "
                         "are appended to the recipe")
    ap.add_argument("--budget", default="avg_bits",
                    choices=["avg_bits", "bytes"],
                    help="meaning of --auto-bits: numel-weighted average "
                         "bits, or total serving bytes (packed codes + "
                         "affine grid)")
    ap.add_argument("--alloc-objective", default="combined",
                    choices=["mse", "fisher", "combined"],
                    help="sensitivity metric the allocator minimizes")
    ap.add_argument("--scan-chunk", type=int, default=DEFAULT_CHUNK,
                    help="optimization steps fused per device dispatch in "
                         "the scanned engine")
    ap.add_argument("--mesh", default=None, choices=["debug", "production"],
                    help="run reconstruction data-parallel over a device "
                         "mesh: calibration built per-host "
                         "(SyntheticTokens.batch shards + straggler loss "
                         "weight), streams sharded over the data axes, "
                         "states replicated. debug = 2x4 (8 devices, force "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8); production = 16x16")
    ap.add_argument("--multi-pod", action="store_true",
                    help="with --mesh: add the pod axis (pod, data, model)")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="enable the telemetry layer (repro.obs): spans/"
                         "counters/histograms stream to DIR/events.jsonl "
                         "as manifest-stamped JSONL")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in jax.profiler.trace, emitting a "
                         "perfetto-loadable trace dir under --telemetry DIR "
                         "(or /tmp/repro_profile)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    if args.from_ckpt:
        state, _ = CheckpointManager(args.from_ckpt).restore()
        params = state["params"]
    else:
        print("no --from-ckpt: quantizing randomly-initialized weights "
              "(structure demo)")
        params = model.init(jax.random.key(0))

    recipe = QuantRecipe(method=args.method, setting=args.setting,
                         recon=args.recon, w_bits=args.w_bits,
                         w_granularity=args.w_granularity,
                         a_bits=args.a_bits, iters=args.iters, lr=args.lr,
                         batch_size=min(16, args.calib),
                         rules=tuple(args.rule))
    src = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq, seed=0)
    mesh, sample_weight = None, None
    if args.mesh is not None:
        mesh = build_mesh(args.mesh, multi_pod=args.multi_pod)
        cal, sample_weight = build_sharded_calibration(src, args.calib, mesh)
    else:
        cal = CalibrationSet.build(src, args.calib)

    from repro.obs.sink import JsonlSink, RunManifest
    from repro.obs.telemetry import TELEMETRY
    if args.telemetry:
        manifest = RunManifest.collect(backend=args.backend, mesh=args.mesh,
                                       recipe=recipe)
        events = os.path.join(args.telemetry, "events.jsonl")
        TELEMETRY.enable(sink=JsonlSink(events), manifest=manifest)
        print(f"telemetry: streaming to {events} "
              f"(git {manifest.git_sha}, schema {manifest.schema_version})")
    if args.profile:
        from repro.obs import profiler
        profiler.start(os.path.join(args.telemetry or "/tmp/repro_profile",
                                    "profile"))

    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)

    reset_engine_stats()
    alloc_meta = None
    if args.auto_bits is not None:
        recipe, alloc_meta = apply_auto_bits(
            blocks, recipe, x0, value=args.auto_bits, budget=args.budget,
            objective=args.alloc_objective, resume_dir=args.resume_dir,
            mesh=mesh)
        if alloc_meta:
            TELEMETRY.emit({"kind": "allocation",
                            "digest": str(alloc_meta.get("digest", "")),
                            "name": alloc_meta.get("name")})

    if recipe.rules:
        overridden = [(n, p.summary()) for b in blocks
                      for n, p in site_plans(b, recipe).items()
                      if recipe.overrides_for(n)]
        print(f"rules override {len(overridden)} site(s):")
        for n, s in overridden:
            print(f"  {n}: {s}")
    finalized, astates, reports = quantize_blocks(
        blocks, recipe, x0, checkpoint_dir=args.resume_dir,
        progress=lambda s: print(s, flush=True),
        chunk=args.scan_chunk, allocation=alloc_meta,
        mesh=mesh, sample_weight=sample_weight)
    qparams = assemble(finalized)

    stats = engine_stats()
    # blocks replayed from a resume checkpoint carry no loop timing
    # (steps_per_s=0.0): only count units reconstructed by this process
    ran = [r for r in reports if r.steps_per_s > 0]
    steps = sum(r.iters for r in ran)
    loop_s = sum(r.iters / r.steps_per_s for r in ran)
    print(f"recon: {steps} steps over {len(ran)} unit(s) in "
          f"{loop_s:.2f}s ({steps / max(loop_s, 1e-9):.1f} steps/s); "
          f"compiles: step={stats.step_compiles} "
          f"teacher={stats.teacher_compiles} "
          f"student={stats.student_compiles} "
          f"recon_err={stats.recon_error_compiles} "
          f"schedule={stats.schedule_compiles} "
          f"probe={stats.probe_compiles} "
          f"(total {stats.compile_count})", flush=True)

    from repro.obs.sink import current_manifest
    out = args.out or f"/tmp/quantized_{cfg.name}_{args.method}"
    save_pytree(out, {"params": qparams, "astates": astates},
                {"arch": cfg.name, "method": args.method,
                 "w_bits": args.w_bits, "a_bits": args.a_bits,
                 # canonical --rule form so the metadata round-trips
                 "rules": [r.pattern + ":" + ",".join(
                     f"{k}={v}" for k, v in r.overrides)
                     for r in recipe.rules],
                 "manifest": current_manifest().to_dict()})
    tot0 = sum(r.err_before for r in reports)
    tot1 = sum(r.err_after for r in reports)
    print(f"quantized {len(blocks)} blocks: recon err {tot0:.3e} -> "
          f"{tot1:.3e}; saved to {out}")

    if args.serve_smoke:
        serve_smoke(model, qparams, astates, recipe, cfg,
                    backend=args.backend)

    if args.serve:
        serve_engine_run(model, qparams, astates, recipe, cfg,
                         backend=args.backend, slots=args.serve_slots,
                         requests=args.serve_requests,
                         max_new=args.serve_max_new,
                         kv_quant=not args.no_kv_quant)

    if args.profile:
        from repro.obs import profiler
        profiler.stop()
    if TELEMETRY.enabled:
        # final aggregate record, then flush/close the sink
        TELEMETRY.emit({"kind": "snapshot", **TELEMETRY.snapshot()})
        TELEMETRY.disable()

    if args.analyze or args.analyze_mem:
        from repro.analysis.lint import run_analysis
        rep = run_analysis(mem=args.analyze_mem)
        print(rep.pretty())
        if rep.exit_code():
            raise SystemExit("quantlint: error findings (see above)")


def build_mesh(kind: str, *, multi_pod: bool = False):
    """--mesh flag -> jax Mesh, with an actionable error when the process
    does not expose enough devices (the debug mesh is 8 virtual CPU devices
    in both its single- and multi-pod shapes — (2,4) and (2,2,2))."""
    need = 8 if kind == "debug" else (512 if multi_pod else 256)
    have = jax.device_count()
    if have < need:
        raise SystemExit(
            f"--mesh {kind} needs {need} devices but this process sees "
            f"{have}; on CPU run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "(debug mesh) or launch on the real pod")
    if kind == "debug":
        return make_debug_mesh(multi_pod=multi_pod)
    return make_production_mesh(multi_pod=multi_pod)


def build_sharded_calibration(src, n_calib: int, mesh):
    """Per-host calibration for a mesh run: one simulated host per
    data-parallel slice fetches exactly its ``SyntheticTokens.batch`` shard;
    the straggler policy assembles them and its loss weight feeds the recon
    objective. Returns (CalibrationSet, (N,) sample weight)."""
    n_hosts = axis_size(mesh, dp_axes(mesh))
    if n_calib % n_hosts:
        raise SystemExit(
            f"--calib {n_calib} does not divide over the mesh's "
            f"{n_hosts} data-parallel hosts; pick a multiple of {n_hosts}")
    cal, weight = CalibrationSet.build_sharded(src, n_calib, n_hosts)
    print(f"calibration: {n_calib} samples assembled from {n_hosts} "
          f"per-host shards (dp axes {dp_axes(mesh)}, "
          f"weight mass {float(weight.sum()):.0f}/{len(cal)})")
    if float(weight.sum()) == len(cal):
        # no host missed the deadline: the weighted mean would equal the
        # plain mean, but only sample_weight=None keeps the objective on the
        # exact reduction the recorded trajectories (and the sharded parity
        # suite) pin — so drop the all-ones mask
        return cal, None
    return cal, weight


def apply_auto_bits(blocks, recipe, x0, *, value: float, budget: str,
                    objective: str = "combined", resume_dir=None, mesh=None):
    """Probe -> solve -> append emitted rules. Returns (recipe, alloc_meta).

    When ``resume_dir`` holds an ``allocation.json`` from an earlier run the
    recorded allocation is validated against the requested budget and reused
    (no re-probe) so the resumed run quantizes under the identical rules;
    a different budget fails loudly.
    """
    from repro.allocate import AllocationReport, Budget, auto_allocate

    kind = "weight_bytes" if budget == "bytes" else budget
    report = None
    if resume_dir is not None:
        report = AllocationReport.load(resume_dir)
    if report is not None:
        want = {"kind": kind, "value": value}
        if report.budget != want or report.objective != objective:
            raise ValueError(
                f"resume dir {resume_dir} holds allocation "
                f"{report.name!r} for budget {report.budget} / objective "
                f"{report.objective!r} but this run requests {want} / "
                f"{objective!r}; re-run with the original settings or a "
                "fresh checkpoint dir")
        have = {n for b in blocks for n in b.sites}
        stale = sorted(set(report.bits()) - have)
        if stale:
            raise ValueError(
                f"resume dir {resume_dir} holds allocation {report.name!r} "
                f"for sites this model does not have (e.g. {stale[:3]}); "
                "its rules would silently match nothing — re-probe with a "
                "fresh checkpoint dir")
        print(f"reusing recorded allocation from {resume_dir}:")
    else:
        report = auto_allocate(blocks, recipe, x0, Budget(kind, value),
                               objective=objective, mesh=mesh)
        if resume_dir is not None:
            report.save(resume_dir)
    print(report.pretty(), flush=True)
    return recipe.with_rules(*report.rules()), report.meta()


def serve_smoke(model, qparams, astates, recipe, cfg, *, backend: str = "auto",
                batch: int = 2, prompt_len: int = 16, steps: int = 8) -> float:
    """Short deploy-mode decode through the kernel serving path.

    Prefills a tiny batch and times ``steps`` greedy decode steps with the
    quantized weights dispatched through ``kernels/ops.qtensor_matmul`` under
    the requested backend. Returns us/step (also printed, with the effective
    weight bytes each step moves)."""
    import jax.numpy as jnp

    from repro.core.context import QuantCtx
    from repro.core.qtensor import tree_weight_bytes

    from repro.serve.smoke import serve_capability

    ok, reason = serve_capability(model)
    if not ok:
        # machine-readable skip (same contract as the serve bench row)
        print(f"serve-smoke: skipped arch={cfg.name} reason={reason}")
        return float("nan")
    ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates,
                   backend=backend)
    tokens = jax.random.randint(jax.random.key(0), (batch, prompt_len), 0,
                                cfg.vocab)
    cache = model.init_cache(batch, prompt_len + steps + 1)
    prefill = jax.jit(lambda p, t, c: model.prefill(p, t, c, ctx))
    step = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos, ctx))
    from repro.obs.telemetry import Stopwatch

    _, cache = prefill(qparams, tokens, cache)
    tok = tokens[:, -1:]
    logits, cache = step(qparams, tok, cache, jnp.int32(prompt_len))  # warm
    sw = Stopwatch()
    for i in range(steps):
        logits, cache = step(qparams, tok, cache,
                             jnp.int32(prompt_len + 1 + i))
    jax.block_until_ready(logits)
    us = sw.elapsed_us() / steps
    wbytes = tree_weight_bytes(qparams)
    print(f"serve-smoke[{backend}]: {us:.1f} us/step, "
          f"weight bytes/step {wbytes / 2**20:.2f} MiB")
    return us


def serve_engine_run(model, qparams, astates, recipe, cfg, *,
                     backend: str = "auto", slots: int = 4,
                     requests: int = 8, max_new: int = 16,
                     kv_quant: bool = True):
    """Run the continuous-batching engine on a synthetic request stream.

    Deploy-mode weights (kernel dispatch per ``backend``), bucketed AOT
    prefill, slot decode with the int8 KV cache by default. Prints sustained
    tokens/s at full occupancy, HBM per slot, per-bucket prefill latency
    (p50 over the run, not just the last call), per-request TTFT/queue-wait
    percentiles, and the (flat) compile count. Degrades with a
    machine-readable skip reason on families the slot layout cannot
    serve."""
    import numpy as np

    from repro.core.context import QuantCtx
    from repro.obs.telemetry import Stopwatch
    from repro.serve import EngineConfig, Request, Scheduler, ServeEngine
    from repro.serve.smoke import serve_capability

    ok, reason = serve_capability(model, engine=True, kv_quant=kv_quant)
    if not ok:
        print(f"serve: skipped arch={cfg.name} reason={reason}")
        return None
    ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates,
                   backend=backend)
    max_len = max(32, 2 * max_new)
    engine = ServeEngine(model, qparams, ctx,
                         EngineConfig(slots=slots, max_len=max_len,
                                      prefill_group=2, kv_quant=kv_quant))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(4, 16)),
                                        ).astype(np.int32),
                    max_new=max_new)
            for i in range(requests)]
    sw = Stopwatch()
    with Scheduler(engine) as sched:
        outs = sched.run(reqs)
        st = sched.stats()
    dt = sw.elapsed_s()
    n_tok = sum(len(v) for v in outs.values())
    pf = " ".join(f"b{b}={s['p50']:.0f}us(n={s['count']})"
                  for b, s in sorted(st["prefill_us"].items()))
    rq = st["requests"]
    print(f"serve[{backend}] kv={'int8' if kv_quant else 'fp'}: "
          f"{requests} requests x {max_new} tokens on {slots} slots -> "
          f"{n_tok / dt:.1f} tokens/s, "
          f"hbm_per_slot {st['hbm_per_slot_MiB']:.4f} MiB, "
          f"compile_count {st['compile_count']} "
          f"(buckets {st['buckets']}), prefill {pf}")
    print(f"serve requests: ttft p50={rq['ttft_us']['p50']:.0f}us "
          f"p95={rq['ttft_us']['p95']:.0f}us, "
          f"queue_wait p50={rq['queue_wait_us']['p50']:.0f}us "
          f"p95={rq['queue_wait_us']['p95']:.0f}us, "
          f"detok_errors={rq['detok_errors']}")
    return st


if __name__ == "__main__":
    main()
