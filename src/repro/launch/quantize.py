"""Production PTQ launcher: load a trained checkpoint, run block-wise
FlexRound (or a baseline), export integer weights.

    PYTHONPATH=src python -m repro.launch.quantize --arch smollm-135m \
        --smoke --method flexround --w-bits 8 --a-bits 8

Fault tolerance: per-block PTQ checkpoints (--resume-dir) — a preempted run
resumes at the first unfinished block with identical RNG.
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointManager, save_pytree
from repro.configs import get_config, get_smoke_config
from repro.core import QuantRecipe
from repro.core.reconstruct import quantize_blocks
from repro.data import CalibrationSet, SyntheticTokens
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="flexround",
                    choices=["rtn", "adaround", "adaquant", "flexround"])
    ap.add_argument("--setting", default="qdrop", choices=["brecq", "qdrop"])
    ap.add_argument("--recon", default="block", choices=["block", "layer"])
    ap.add_argument("--w-bits", type=int, default=8)
    ap.add_argument("--a-bits", type=int, default=None)
    ap.add_argument("--w-granularity", default="per_channel")
    ap.add_argument("--calib", type=int, default=64)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--from-ckpt", default=None,
                    help="CheckpointManager dir of a trained model")
    ap.add_argument("--resume-dir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    if args.from_ckpt:
        state, _ = CheckpointManager(args.from_ckpt).restore()
        params = state["params"]
    else:
        print("no --from-ckpt: quantizing randomly-initialized weights "
              "(structure demo)")
        params = model.init(jax.random.key(0))

    recipe = QuantRecipe(method=args.method, setting=args.setting,
                         recon=args.recon, w_bits=args.w_bits,
                         w_granularity=args.w_granularity,
                         a_bits=args.a_bits, iters=args.iters, lr=args.lr,
                         batch_size=min(16, args.calib))
    src = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq, seed=0)
    cal = CalibrationSet.build(src, args.calib)
    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)
    finalized, astates, reports = quantize_blocks(
        blocks, recipe, x0, checkpoint_dir=args.resume_dir,
        progress=lambda s: print(s, flush=True))
    qparams = assemble(finalized)

    out = args.out or f"/tmp/quantized_{cfg.name}_{args.method}"
    save_pytree(out, {"params": qparams, "astates": astates},
                {"arch": cfg.name, "method": args.method,
                 "w_bits": args.w_bits, "a_bits": args.a_bits})
    tot0 = sum(r.err_before for r in reports)
    tot1 = sum(r.err_after for r in reports)
    print(f"quantized {len(blocks)} blocks: recon err {tot0:.3e} -> "
          f"{tot1:.3e}; saved to {out}")


if __name__ == "__main__":
    main()
