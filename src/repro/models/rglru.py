"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern "RRA" (two recurrent blocks per local-attention block). The
RG-LRU linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t) runs as
a jax.lax.associative_scan over the sequence (log-depth on TPU) for
train/prefill, and as an O(1) state update for decode — which is why this
arch runs the long_500k cell. Local attention decodes against a ring-buffer
cache of ``local_window`` slots so decode memory is O(window), not O(seq).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.reconstruct import BlockHandle, Site
from repro.models import attention as attn
from repro.models import common
from repro.serve import kv as skv

C_RGLRU = 8.0


# ----------------------------------------------------------------- RG-LRU
def rglru_params(key, cfg, dtype) -> dict:
    R = cfg.lru_width
    ks = jax.random.split(key, 4)
    s = R**-0.5
    # Lambda init so that a = exp(-c*softplus(L)*r) sits in (0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, R).astype(jnp.float32)) / C_RGLRU))
    return {
        "w_a": jax.random.normal(ks[0], (R, R), dtype) * s,
        "b_a": jnp.zeros((R,), jnp.float32),
        "w_i": jax.random.normal(ks[1], (R, R), dtype) * s,
        "b_i": jnp.zeros((R,), jnp.float32),
        "lam": lam,
    }


def _rglru_gates(p, x, ctx, name):
    r = jax.nn.sigmoid(
        ctx.linear(f"{name}.w_a", x, p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(
        ctx.linear(f"{name}.w_i", x, p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r  # (B,S,R), negative
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (
        i * x.astype(jnp.float32))
    return a, b


def rglru_scan(p, x, ctx, name, h0=None):
    """x (B,S,R) -> (y (B,S,R), h_final (B,R)) via associative scan."""
    a, b = _rglru_gates(p, x, ctx, name)
    if h0 is not None:  # fold initial state into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(p, x, ctx, name, h_prev):
    """x (B,1,R), h_prev (B,R) -> (y (B,1,R), h (B,R))."""
    a, b = _rglru_gates(p, x, ctx, name)
    h = a[:, 0] * h_prev.astype(jnp.float32) + b[:, 0]
    return h[:, None, :].astype(x.dtype), h


# ------------------------------------------------------------ block params
def recurrent_block_params(key, cfg, dtype) -> dict:
    D, R = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 5)
    return {
        "ln": common.norm_params("rmsnorm", D, dtype),
        "w_x": jax.random.normal(ks[0], (D, R), dtype) * D**-0.5,
        "w_gate": jax.random.normal(ks[1], (D, R), dtype) * D**-0.5,
        "conv_w": jax.random.normal(ks[2], (4, R), dtype) * 0.2,
        "conv_b": jnp.zeros((R,), dtype),
        "rglru": rglru_params(ks[3], cfg, dtype),
        "w_o": jax.random.normal(ks[4], (R, D), dtype) * R**-0.5,
    }


def attn_block_params(key, cfg, dtype) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = D**-0.5
    return {
        "ln": common.norm_params("rmsnorm", D, dtype),
        "wq": jax.random.normal(ks[0], (D, H * Dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (D, Hkv * Dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (D, Hkv * Dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (H * Dh, D), dtype) * (H * Dh) ** -0.5,
    }


def mlp_block_params(key, cfg, dtype) -> dict:
    return {
        "ln": common.norm_params("rmsnorm", cfg.d_model, dtype),
        "mlp": common.mlp_params(key, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _causal_conv(x, w, bias, init=None):
    K = w.shape[0]
    if init is None:
        ext = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ext = jnp.concatenate([init.astype(x.dtype), x], axis=1)
    out = sum(ext[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + bias


# ----------------------------------------------------------- block applies
def recurrent_block(p, x, cfg, ctx, name, h0=None, conv_init=None,
                    return_state=False):
    res = x
    h = common.apply_norm("rmsnorm", x, p["ln"])
    xr = ctx.linear(f"{name}.w_x", h, p["w_x"])
    conv_tail = xr[:, -3:, :]
    xr = _causal_conv(xr, p["conv_w"], p["conv_b"], conv_init)
    y, h_last = rglru_scan(p["rglru"], xr, ctx, f"{name}.rglru", h0)
    gate = jax.nn.gelu(
        ctx.linear(f"{name}.w_gate", h, p["w_gate"]).astype(jnp.float32))
    out = ctx.linear(f"{name}.w_o", (y.astype(jnp.float32) * gate).astype(x.dtype),
                     p["w_o"])
    if return_state:
        return res + out, (h_last, conv_tail)
    return res + out


def recurrent_block_step(p, x, cfg, ctx, name, h_prev, conv_state):
    """Decode step. conv_state (B,3,R) raw pre-conv inputs."""
    res = x
    h = common.apply_norm("rmsnorm", x, p["ln"])
    xr = ctx.linear(f"{name}.w_x", h, p["w_x"])
    window = jnp.concatenate([conv_state.astype(xr.dtype), xr], axis=1)
    conv_new = window[:, 1:, :]
    xc = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    y, h_new = rglru_step(p["rglru"], xc[:, None, :].astype(x.dtype), ctx,
                          f"{name}.rglru", h_prev)
    gate = jax.nn.gelu(
        ctx.linear(f"{name}.w_gate", h, p["w_gate"]).astype(jnp.float32))
    out = ctx.linear(f"{name}.w_o", (y.astype(jnp.float32) * gate).astype(x.dtype),
                     p["w_o"])
    return res + out, h_new, conv_new


def local_attn_block(p, x, cfg, ctx, name, sin, cos, return_kv=False):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    res = x
    h = common.apply_norm("rmsnorm", x, p["ln"])
    q = ctx.linear(f"{name}.wq", h, p["wq"]).reshape(B, S, H, Dh)
    k = ctx.linear(f"{name}.wk", h, p["wk"]).reshape(B, S, Hkv, Dh)
    v = ctx.linear(f"{name}.wv", h, p["wv"]).reshape(B, S, Hkv, Dh)
    q = common.apply_rope(q, sin, cos)
    k = common.apply_rope(k, sin, cos)
    o = attn.attention(q, k, v, causal=True, window=cfg.local_window,
                       chunk=cfg.attn_chunk)
    out = ctx.linear(f"{name}.wo", o.reshape(B, S, H * Dh), p["wo"])
    if return_kv:
        return res + out, (k, v)
    return res + out


def local_attn_block_step(p, x, cfg, ctx, name, sin, cos, k_ring, v_ring,
                          kpos_ring, pos):
    """Ring-buffer decode. k_ring/v_ring (B,W,Hkv,Dh); kpos_ring (W,)."""
    B = x.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    W = k_ring.shape[1]
    res = x
    h = common.apply_norm("rmsnorm", x, p["ln"])
    q = ctx.linear(f"{name}.wq", h, p["wq"]).reshape(B, 1, H, Dh)
    k = ctx.linear(f"{name}.wk", h, p["wk"]).reshape(B, 1, Hkv, Dh)
    v = ctx.linear(f"{name}.wv", h, p["wv"]).reshape(B, 1, Hkv, Dh)
    q = common.apply_rope(q, sin, cos)
    k = common.apply_rope(k, sin, cos)
    slot = jnp.mod(pos, W)
    k_ring = jax.lax.dynamic_update_slice(k_ring, k.astype(k_ring.dtype),
                                          (0, slot, 0, 0))
    v_ring = jax.lax.dynamic_update_slice(v_ring, v.astype(v_ring.dtype),
                                          (0, slot, 0, 0))
    kpos_ring = jax.lax.dynamic_update_slice(kpos_ring, pos[None], (slot,))
    s = attn._gqa_scores(q, k_ring) * Dh**-0.5  # (B,Hkv,G,1,W)
    valid = (kpos_ring >= 0) & (kpos_ring <= pos) & (kpos_ring > pos - W)
    s = jnp.where(valid[None, None, None, None, :], s, attn.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = attn._gqa_out(pr, v_ring).astype(x.dtype)
    out = ctx.linear(f"{name}.wo", o.reshape(B, 1, H * Dh), p["wo"])
    return res + out, k_ring, v_ring, kpos_ring


# ---------------------------------------------------------------- the LM
class GriffinLM:
    """Unrolled layer pattern (26 layers at 2560 width keeps HLO small)."""

    def __init__(self, cfg):
        self.cfg = cfg
        pat = cfg.layer_pattern or "RRA"
        self.kinds = [pat[i % len(pat)] for i in range(cfg.n_layers)]

    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, cfg.n_layers + 3)
        layers = []
        for i, kind in enumerate(self.kinds):
            k1, k2 = jax.random.split(ks[i])
            p = (recurrent_block_params(k1, cfg, dtype) if kind == "R"
                 else attn_block_params(k1, cfg, dtype))
            layers.append({"mix": p,
                           "ffn": mlp_block_params(k2, cfg, dtype)})
        return {
            "embed": jax.random.normal(ks[-3], (cfg.vocab, cfg.d_model),
                                       dtype) * 0.02,
            "layers": layers,
            "final_norm": common.norm_params("rmsnorm", cfg.d_model, dtype),
            "lm_head": jax.random.normal(ks[-2], (cfg.d_model, cfg.vocab),
                                         dtype) * cfg.d_model**-0.5,
        }

    def _rope(self, B, S, offset=0):
        pos = jnp.broadcast_to(offset + jnp.arange(S)[None], (B, S))
        return common.rope_sin_cos(pos, self.cfg.head_dim, self.cfg.rope_theta)

    def _layer(self, i, p, x, ctx, sin, cos, collect=False):
        cfg = self.cfg
        name = f"layers.{i}"  # canonical "layers.<i>.<site>" naming
        if self.kinds[i] == "R":
            if collect:
                x, st = recurrent_block(p["mix"], x, cfg, ctx, name,
                                        return_state=True)
            else:
                x = recurrent_block(p["mix"], x, cfg, ctx, name)
                st = None
        else:
            if collect:
                x, st = local_attn_block(p["mix"], x, cfg, ctx, name, sin, cos,
                                         return_kv=True)
            else:
                x = local_attn_block(p["mix"], x, cfg, ctx, name, sin, cos)
                st = None
        h = common.apply_norm("rmsnorm", x, p["ffn"]["ln"])
        x = x + common.mlp(p["ffn"]["mlp"], h, ctx, f"{name}.mlp", cfg.act)
        return x, st

    def backbone(self, params, tokens, ctx, collect=False):
        cfg = self.cfg
        x = common.embed_tokens(params["embed"], tokens, cfg.emb_mult)
        B, S, _ = x.shape
        sin, cos = self._rope(B, S)
        states = []
        for i, p in enumerate(params["layers"]):
            x, st = self._layer(i, p, x, ctx, sin, cos, collect)
            states.append(st)
        x = common.apply_norm("rmsnorm", x, params["final_norm"])
        return x, states

    def loss(self, params, batch, ctx):
        x, _ = self.backbone(params, batch["tokens"], ctx)
        ce = common.fused_cross_entropy(x, params["lm_head"], batch["labels"],
                                        batch.get("mask"), self.cfg.xent_chunk)
        return ce, {"ce": ce}

    def init_cache(self, batch: int, max_len: int, dtype=None,
                   kv_quant: bool = False):
        cfg = self.cfg
        skv.check_kv_quant_supported(cfg, kv_quant, family="hybrid")
        dtype = dtype or jnp.dtype(cfg.dtype)
        W = min(cfg.local_window or max_len, max_len)
        cache: Dict[str, Any] = {"layers": []}
        for kind in self.kinds:
            if kind == "R":
                cache["layers"].append({
                    "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
                    "conv": jnp.zeros((batch, 3, cfg.lru_width), jnp.float32),
                })
            else:
                cache["layers"].append({
                    "k": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim),
                                   dtype),
                    "v": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim),
                                   dtype),
                    "kpos": jnp.full((W,), -1, jnp.int32),
                })
        return cache

    def prefill(self, params, tokens, cache, ctx):
        x, states = self.backbone(params, tokens, ctx, collect=True)
        S = tokens.shape[1]
        W = cache["layers"][self._first_attn()]["k"].shape[1] \
            if "A" in self.kinds else 0
        new_layers = []
        for i, (st, c) in enumerate(zip(states, cache["layers"])):
            if self.kinds[i] == "R":
                h_last, conv_tail = st
                ct = conv_tail
                if ct.shape[1] < 3:  # short prefill: left-pad
                    ct = jnp.pad(ct, ((0, 0), (3 - ct.shape[1], 0), (0, 0)))
                new_layers.append({"h": h_last.astype(jnp.float32),
                                   "conv": ct.astype(jnp.float32)})
            else:
                k, v = st
                n = min(W, S)
                ks, vs = k[:, -n:], v[:, -n:]
                positions = jnp.arange(S - n, S)
                slots = jnp.mod(positions, W)
                kc = c["k"].at[:, slots].set(ks.astype(c["k"].dtype))
                vc = c["v"].at[:, slots].set(vs.astype(c["v"].dtype))
                kp = c["kpos"].at[slots].set(positions)
                new_layers.append({"k": kc, "v": vc, "kpos": kp})
        return x[:, -1:], {"layers": new_layers}

    def _first_attn(self):
        return self.kinds.index("A") if "A" in self.kinds else 0

    def decode_step(self, params, token, cache, pos, ctx):
        cfg = self.cfg
        x = common.embed_tokens(params["embed"], token, cfg.emb_mult)
        B = x.shape[0]
        pos_arr = jnp.full((B, 1), pos)
        sin, cos = common.rope_sin_cos(pos_arr, cfg.head_dim, cfg.rope_theta)
        new_layers = []
        for i, (p, c) in enumerate(zip(params["layers"], cache["layers"])):
            name = f"layers.{i}"
            if self.kinds[i] == "R":
                x, h_new, conv_new = recurrent_block_step(
                    p["mix"], x, cfg, ctx, name, c["h"], c["conv"])
                new_layers.append({"h": h_new, "conv": conv_new})
            else:
                x, kc, vc, kp = local_attn_block_step(
                    p["mix"], x, cfg, ctx, name, sin, cos, c["k"], c["v"],
                    c["kpos"], pos)
                new_layers.append({"k": kc, "v": vc, "kpos": kp})
            h = common.apply_norm("rmsnorm", x, p["ffn"]["ln"])
            x = x + common.mlp(p["ffn"]["mlp"], h, ctx, f"{name}.mlp", cfg.act)
        x = common.apply_norm("rmsnorm", x, params["final_norm"])
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits, {"layers": new_layers}

    def quant_blocks(self, params, batch_tokens):
        cfg = self.cfg
        x0 = common.embed_tokens(params["embed"], batch_tokens, cfg.emb_mult)
        B, S = batch_tokens.shape
        sin, cos = self._rope(1, S)  # batch-agnostic rope for recon batches
        mlp_names = ["w_up", "w_down"] + (
            ["w_gate"] if cfg.act in ("swiglu", "geglu") else [])
        blocks = []
        call_token = object()  # compiled recon steps shared per layer kind
        for i, p_l in enumerate(params["layers"]):
            name = f"layers.{i}"
            sites = {f"{name}.mlp.{n}": Site(("ffn", "mlp", n))
                     for n in mlp_names}
            if self.kinds[i] == "R":
                for n in ("w_x", "w_gate", "w_o"):
                    sites[f"{name}.{n}"] = Site(("mix", n))
                for n in ("w_a", "w_i"):
                    sites[f"{name}.rglru.{n}"] = Site(("mix", "rglru", n))
            else:
                for n in ("wq", "wk", "wv", "wo"):
                    sites[f"{name}.{n}"] = Site(("mix", n))

            def apply_fn(p, x, ctx, _i=i):
                y, _ = self._layer(_i, p, x, ctx, sin, cos)
                return y

            blocks.append(BlockHandle(name, p_l, apply_fn, sites,
                                      apply_key=(call_token, self.kinds[i])))

        def assemble(finalized):
            out = dict(params)
            out["layers"] = list(finalized)
            return out

        return x0, blocks, assemble
