"""Mamba2 (state-space duality / SSD) language model.

Implements the chunked SSD algorithm (Dao & Gu 2024, "ssd_minimal") in
matmul-friendly einsums: intra-chunk quadratic blocks + an inter-chunk state
recurrence — exactly the structure the MXU wants. Decode is the O(1)-state
recurrent update, which is why mamba2 runs the long_500k cell.

Quantized sites: in_proj / out_proj (the two big matmuls). conv1d (depthwise,
tiny), A/dt/D/norm params stay fp — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.context import QuantCtx
from repro.core.reconstruct import BlockHandle, Site
from repro.models import common
from repro.serve import kv as skv


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def layer_params(key, cfg, dtype) -> dict:
    d_inner, n_heads, conv_dim = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * cfg.ssm_state + n_heads  # z, x, B, C, dt
    return {
        "ln": common.norm_params("rmsnorm", D, dtype),
        "in_proj": jax.random.normal(ks[0], (D, d_proj), dtype) * D**-0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": common.norm_params("rmsnorm", d_inner, dtype),
        "out_proj": jax.random.normal(ks[2], (d_inner, D), dtype) * d_inner**-0.5,
    }


def _segsum(x):
    """x (..., T) -> (..., T, T): sum_{j<i..} masked lower-triangular."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dA, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    x  (b, s, h, p)   inputs (already multiplied by dt)
    dA (b, s, h)      per-step log decay (negative)
    Bm (b, s, n), Cm (b, s, n)  input/output projections (ngroups=1)
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    c = s // chunk
    xc = x.reshape(b, c, chunk, h, p)
    Ac = dA.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    Bc = Bm.reshape(b, c, chunk, n)
    Cc = Cm.reshape(b, c, chunk, n)

    A_cum = jnp.cumsum(Ac, axis=-1)
    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(Ac))  # (b,h,c,l,l)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)
    # 2. per-chunk end states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)  # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)
    # 3. inter-chunk recurrence (sequential scan over chunks)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    chunk_decay = jnp.exp(A_cum[..., -1])  # (b,h,c)

    def body(carry, inp):
        st_in = carry
        st_chunk, dec = inp  # (b,h,p,n), (b,h)
        st_out = st_in * dec[..., None, None] + st_chunk
        return st_out, st_in  # emit state *entering* this chunk

    (final_state, prev_states) = jax.lax.scan(
        body, init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)
    # 4. inter-chunk output contribution
    state_decay = jnp.exp(A_cum)  # (b,h,c,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv along seq: xbc (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return out + bias


def _split_proj(zxbcdt, cfg):
    d_inner, n_heads, conv_dim = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt


def layer_forward(p, u, cfg, ctx: QuantCtx, name: str, init_state=None,
                  conv_init=None):
    """Full-sequence mamba2 layer. Returns (y, (conv_tail, final_state))."""
    d_inner, n_heads, conv_dim = _dims(cfg)
    B_, S, D = u.shape
    res = u
    h = common.apply_norm("rmsnorm", u, p["ln"])
    zxbcdt = ctx.linear(f"{name}.in_proj", h, p["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    if conv_init is not None:
        xbc_ext = jnp.concatenate([conv_init.astype(xbc.dtype), xbc], axis=1)
        xbc_conv = _causal_conv(xbc_ext, p["conv_w"], p["conv_b"])[:, -S:]
    else:
        xbc_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc_conv = jax.nn.silu(xbc_conv.astype(jnp.float32))
    x = xbc_conv[..., :d_inner].reshape(B_, S, n_heads, cfg.ssm_headdim)
    Bm = xbc_conv[..., d_inner:d_inner + cfg.ssm_state]
    Cm = xbc_conv[..., d_inner + cfg.ssm_state:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dA = -jnp.exp(p["a_log"]) * dt  # negative log decay
    y, final_state = ssd_chunked(x * dt[..., None], dA, Bm, Cm,
                                 cfg.attn_chunk, init_state)
    y = y + p["d_skip"][None, None, :, None] * x
    y = y.reshape(B_, S, d_inner)
    y = common.rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype),
                       p["gate_norm"]["scale"])
    out = ctx.linear(f"{name}.out_proj", y, p["out_proj"])
    conv_tail = xbc[:, -(cfg.ssm_conv - 1):, :]  # raw (pre-conv) tail
    return res + out, (conv_tail, final_state)


def layer_decode(p, u, cfg, ctx: QuantCtx, name: str, conv_state, ssm_state):
    """Single-token step. conv_state (B, K-1, conv_dim) raw inputs;
    ssm_state (B, H, P, N). Returns (y, conv_state', ssm_state')."""
    d_inner, n_heads, conv_dim = _dims(cfg)
    B_, _, D = u.shape
    res = u
    h = common.apply_norm("rmsnorm", u, p["ln"])
    zxbcdt = ctx.linear(f"{name}.in_proj", h, p["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)  # (B,1,*)
    window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    conv_state_new = window[:, 1:, :]
    xbc_conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xbc_conv = jax.nn.silu(xbc_conv)[:, None, :]  # (B,1,conv_dim)
    x = xbc_conv[..., :d_inner].reshape(B_, n_heads, cfg.ssm_headdim)
    Bm = xbc_conv[:, 0, d_inner:d_inner + cfg.ssm_state]
    Cm = xbc_conv[:, 0, d_inner + cfg.ssm_state:]

    dt_ = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dA = jnp.exp(-jnp.exp(p["a_log"]) * dt_)  # (B,H)
    xdt = x * dt_[..., None]
    ssm_new = (ssm_state * dA[..., None, None]
               + jnp.einsum("bhp,bn->bhpn", xdt, Bm))
    y = jnp.einsum("bhpn,bn->bhp", ssm_new, Cm) + p["d_skip"][None, :, None] * x
    y = y.reshape(B_, 1, d_inner)
    y = common.rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype),
                       p["gate_norm"]["scale"])
    out = ctx.linear(f"{name}.out_proj", y, p["out_proj"])
    return res + out, conv_state_new, ssm_new


class MambaLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k0, k1, k2 = jax.random.split(key, 3)
        ks = jax.random.split(k1, cfg.n_layers)
        return {
            "embed": jax.random.normal(k0, (cfg.vocab, cfg.d_model), dtype) * 0.02,
            "layers": jax.vmap(lambda k: layer_params(k, cfg, dtype))(ks),
            "final_norm": common.norm_params("rmsnorm", cfg.d_model, dtype),
            "lm_head": jax.random.normal(k2, (cfg.d_model, cfg.vocab), dtype)
            * cfg.d_model**-0.5,
        }

    def backbone(self, params, tokens, ctx, collect_state=False):
        cfg = self.cfg
        x = common.embed_tokens(params["embed"], tokens)

        def body(carry, p_l):
            h = carry
            y, _ = layer_forward(p_l, h, cfg, ctx, "layers")
            return y, None

        if cfg.remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = common.scan_layers(body, x, params["layers"])
        return common.apply_norm("rmsnorm", x, params["final_norm"])

    def loss(self, params, batch, ctx):
        x = self.backbone(params, batch["tokens"], ctx)
        ce = common.fused_cross_entropy(x, params["lm_head"], batch["labels"],
                                        batch.get("mask"), self.cfg.xent_chunk)
        return ce, {"ce": ce}

    def init_cache(self, batch: int, max_len: int, dtype=None,
                   kv_quant: bool = False):
        cfg = self.cfg
        skv.check_kv_quant_supported(cfg, kv_quant, family="ssm")
        d_inner, n_heads, conv_dim = _dims(cfg)
        L = cfg.n_layers
        return {
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim),
                              jnp.float32),
            "ssm": jnp.zeros((L, batch, n_heads, cfg.ssm_headdim,
                              cfg.ssm_state), jnp.float32),
        }

    def prefill(self, params, tokens, cache, ctx):
        cfg = self.cfg
        x = common.embed_tokens(params["embed"], tokens)

        def body(carry, p_l):
            h = carry
            y, (conv_tail, state) = layer_forward(p_l, h, cfg, ctx, "layers")
            return y, (conv_tail, state)

        x, (convs, states) = common.scan_layers(body, x, params["layers"])
        cache = {"conv": convs.astype(cache["conv"].dtype),
                 "ssm": states.astype(cache["ssm"].dtype)}
        x = common.apply_norm("rmsnorm", x, params["final_norm"])
        return x[:, -1:], cache

    def decode_step(self, params, token, cache, pos, ctx):
        cfg = self.cfg
        x = common.embed_tokens(params["embed"], token)

        def body(carry, inp):
            h = carry
            p_l, conv_l, ssm_l = inp
            y, conv_n, ssm_n = layer_decode(p_l, h, cfg, ctx, "layers",
                                            conv_l, ssm_l)
            return y, (conv_n, ssm_n)

        x, (convs, ssms) = common.scan_layers(
            body, x, params["layers"], cache["conv"], cache["ssm"])
        cache = {"conv": convs, "ssm": ssms}
        x = common.apply_norm("rmsnorm", x, params["final_norm"])
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits, cache

    def quant_blocks(self, params, batch_tokens):
        cfg = self.cfg
        x0 = common.embed_tokens(params["embed"], batch_tokens)
        blocks = []
        sites = {"layers.in_proj": Site(("in_proj",)),
                 "layers.out_proj": Site(("out_proj",))}
        call_token = object()  # share compiled recon steps across layers
        for i in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a: a[i], params["layers"])
            bname = f"layers.{i}"  # canonical "layers.<i>.<site>" naming
            bsites = {k.replace("layers", bname, 1): v for k, v in sites.items()}

            def apply_fn(p, x, ctx, _bn=bname):
                y, _ = layer_forward(p, x, cfg, ctx, _bn)
                return y

            blocks.append(BlockHandle(bname, p_l, apply_fn, bsites,
                                      apply_key=(call_token,)))

        def assemble(finalized):
            out = dict(params)
            out["layers"] = common.stack_layers(finalized)
            return out

        return x0, blocks, assemble
