"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, D) directly to the encoder.
LayerNorm + GELU + biased projections, sinusoidal positions (whisper flavor);
decoder has causal self-attention + cross-attention over encoder output.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.context import QuantCtx
from repro.core.reconstruct import BlockHandle, Site
from repro.models import attention as attn
from repro.models import common
from repro.serve import kv as skv


def _sinusoid(S: int, D: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_params(key, cfg, dtype, cross=False) -> dict:
    D, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = D**-0.5
    return {
        "wq": jax.random.normal(ks[0], (D, H * Dh), dtype) * s,
        "bq": jnp.zeros((H * Dh,), dtype),
        "wk": jax.random.normal(ks[1], (D, H * Dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (D, H * Dh), dtype) * s,
        "bv": jnp.zeros((H * Dh,), dtype),
        "wo": jax.random.normal(ks[3], (H * Dh, D), dtype) * (H * Dh) ** -0.5,
        "bo": jnp.zeros((D,), dtype),
    }


def _enc_layer_params(key, cfg, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = common.mlp_params(k2, cfg.d_model, cfg.d_ff, "gelu", dtype)
    p["b_up"] = jnp.zeros((cfg.d_ff,), dtype)
    p["b_down"] = jnp.zeros((cfg.d_model,), dtype)
    return {
        "ln1": common.norm_params("layernorm", cfg.d_model, dtype),
        "attn": _attn_params(k1, cfg, dtype),
        "ln2": common.norm_params("layernorm", cfg.d_model, dtype),
        "mlp": p,
    }


def _dec_layer_params(key, cfg, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    base = _enc_layer_params(jax.random.fold_in(key, 1), cfg, dtype)
    return {
        "ln1": base["ln1"],
        "attn": _attn_params(k1, cfg, dtype),
        "ln_x": common.norm_params("layernorm", cfg.d_model, dtype),
        "xattn": _attn_params(k2, cfg, dtype, cross=True),
        "ln2": base["ln2"],
        "mlp": base["mlp"],
    }


def _mha(p, xq, xkv, ctx, name, causal, cfg, kv_override=None):
    B, Sq, _ = xq.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q = ctx.linear(f"{name}.wq", xq, p["wq"], p["bq"]).reshape(B, Sq, H, Dh)
    if kv_override is None:
        Sk = xkv.shape[1]
        k = ctx.linear(f"{name}.wk", xkv, p["wk"]).reshape(B, Sk, H, Dh)
        v = ctx.linear(f"{name}.wv", xkv, p["wv"], p["bv"]).reshape(B, Sk, H, Dh)
    else:
        k, v = kv_override
    o = attn.attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    out = ctx.linear(f"{name}.wo", o.reshape(B, Sq, H * Dh), p["wo"], p["bo"])
    return out, (k, v)


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 6)

        def stack(kf, builder, n):
            kk = jax.random.split(kf, n)
            return jax.vmap(lambda k: builder(k, cfg, dtype))(kk)

        return {
            "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                       dtype) * 0.02,
            "enc_layers": stack(ks[1], _enc_layer_params, cfg.enc_layers),
            "enc_norm": common.norm_params("layernorm", cfg.d_model, dtype),
            "dec_layers": stack(ks[2], _dec_layer_params, cfg.n_layers),
            "dec_norm": common.norm_params("layernorm", cfg.d_model, dtype),
            "lm_head": jax.random.normal(ks[3], (cfg.d_model, cfg.vocab),
                                         dtype) * cfg.d_model**-0.5,
        }

    # ------------------------------------------------------------ encoder
    def encode(self, params, frames, ctx):
        """frames: precomputed (B, S_enc, D) embeddings (frontend stub)."""
        cfg = self.cfg
        B, S, D = frames.shape
        x = frames + _sinusoid(S, D).astype(frames.dtype)[None]

        def body(h, p_l):
            z = common.apply_norm("layernorm", h, p_l["ln1"])
            a, _ = _mha(p_l["attn"], z, z, ctx, "enc.attn", False, cfg)
            h = h + a
            z = common.apply_norm("layernorm", h, p_l["ln2"])
            h = h + common.mlp(p_l["mlp"], z, ctx, "enc.mlp", "gelu")
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return common.apply_norm("layernorm", x, params["enc_norm"])

    # ------------------------------------------------------------ decoder
    def _dec_layer(self, p_l, h, enc_out, ctx, name, collect=False,
                   self_kv=None, cross_kv=None, pos=None):
        cfg = self.cfg
        z = common.apply_norm("layernorm", h, p_l["ln1"])
        H, Dh = cfg.n_heads, cfg.head_dim
        if self_kv is None:
            a, self_out = _mha(p_l["attn"], z, z, ctx, f"{name}.attn", True,
                               cfg)
        else:  # decode: self_kv = (k, v) or int8 (k, k_scale, v, v_scale)
            B = z.shape[0]
            q = ctx.linear(f"{name}.attn.wq", z, p_l["attn"]["wq"],
                           p_l["attn"]["bq"]).reshape(B, 1, H, Dh)
            if len(self_kv) == 4:
                a = skv.int8_decode_attention(q, *self_kv, pos)
            else:
                a = attn.decode_attention(q, self_kv[0], self_kv[1], pos)
            a = ctx.linear(f"{name}.attn.wo", a.reshape(B, 1, H * Dh),
                           p_l["attn"]["wo"], p_l["attn"]["bo"])
            self_out = None
        h = h + a
        z = common.apply_norm("layernorm", h, p_l["ln_x"])
        if cross_kv is not None and len(cross_kv) == 4:
            # int8 cross cache: every encoder position is valid, so the
            # bidirectional Sq=1 attention is decode_attention at the last
            # encoder index
            B = z.shape[0]
            q = ctx.linear(f"{name}.xattn.wq", z, p_l["xattn"]["wq"],
                           p_l["xattn"]["bq"]).reshape(B, 1, H, Dh)
            xa = skv.int8_decode_attention(q, *cross_kv,
                                           cross_kv[0].shape[1] - 1)
            xa = ctx.linear(f"{name}.xattn.wo", xa.reshape(B, 1, H * Dh),
                            p_l["xattn"]["wo"], p_l["xattn"]["bo"])
            xkv = None
        else:
            xa, xkv = _mha(p_l["xattn"], z, enc_out, ctx, f"{name}.xattn",
                           False, cfg, kv_override=cross_kv)
        h = h + xa
        z = common.apply_norm("layernorm", h, p_l["ln2"])
        h = h + common.mlp(p_l["mlp"], z, ctx, f"{name}.mlp", "gelu")
        if collect:
            return h, (self_out, xkv)
        return h

    def decode_full(self, params, tokens, enc_out, ctx, collect=False):
        cfg = self.cfg
        B, S = tokens.shape
        x = common.embed_tokens(params["embed"], tokens)
        x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)[None]

        def body(h, p_l):
            out = self._dec_layer(p_l, h, enc_out, ctx, "dec", collect=collect)
            if collect:
                return out[0], out[1]
            return out, None

        if cfg.remat and not collect:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, kvs = common.scan_layers(body, x, params["dec_layers"])
        return common.apply_norm("layernorm", x, params["dec_norm"]), kvs

    def loss(self, params, batch, ctx):
        enc_out = self.encode(params, batch["frames"], ctx)
        x, _ = self.decode_full(params, batch["tokens"], enc_out, ctx)
        ce = common.fused_cross_entropy(x, params["lm_head"], batch["labels"],
                                        batch.get("mask"), self.cfg.xent_chunk)
        return ce, {"ce": ce}

    # -------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int, enc_len: int, dtype=None,
                   kv_quant: bool = False):
        """kv_quant quantizes both the growing self-attention cache and the
        static cross (encoder) cache to int8 per-(token, head) absmax."""
        cfg = self.cfg
        skv.check_kv_quant_supported(cfg, kv_quant)
        dtype = dtype or jnp.dtype(cfg.dtype)
        L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
        if kv_quant:
            cache = {}
            for nm, S in (("k", max_len), ("v", max_len),
                          ("xk", enc_len), ("xv", enc_len)):
                cache[nm] = jnp.zeros((L, batch, S, H, Dh), jnp.int8)
                cache[f"{nm}_scale"] = jnp.zeros((L, batch, S, H, 1),
                                                 jnp.float32)
            return cache
        return {
            "k": jnp.zeros((L, batch, max_len, H, Dh), dtype),
            "v": jnp.zeros((L, batch, max_len, H, Dh), dtype),
            "xk": jnp.zeros((L, batch, enc_len, H, Dh), dtype),
            "xv": jnp.zeros((L, batch, enc_len, H, Dh), dtype),
        }

    def prefill(self, params, tokens, frames, cache, ctx):
        enc_out = self.encode(params, frames, ctx)
        x, kvs = self.decode_full(params, tokens, enc_out, ctx, collect=True)
        (sk, sv), (xk, xv) = kvs[0], kvs[1]
        if "k_scale" in cache:
            for nm, t in (("k", sk), ("v", sv)):
                codes, scl = skv.kv_quantize(t)
                cache[nm] = jax.lax.dynamic_update_slice(
                    cache[nm], codes, (0, 0, 0, 0, 0))
                cache[f"{nm}_scale"] = jax.lax.dynamic_update_slice(
                    cache[f"{nm}_scale"], scl, (0, 0, 0, 0, 0))
            for nm, t in (("xk", xk), ("xv", xv)):
                cache[nm], cache[f"{nm}_scale"] = skv.kv_quantize(t)
            return x[:, -1:], cache
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], sk.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], sv.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        cache["xk"] = xk.astype(cache["xk"].dtype)
        cache["xv"] = xv.astype(cache["xv"].dtype)
        return x[:, -1:], cache

    def decode_step(self, params, token, cache, pos, ctx):
        cfg = self.cfg
        B = token.shape[0]
        x = common.embed_tokens(params["embed"], token)
        # sinusoidal position for the current token
        pos_emb = _sinusoid_at(pos, cfg.d_model)
        x = x + pos_emb.astype(x.dtype)[None, None, :]

        def body(carry, inp):
            h, cache = carry
            p_l, i = inp
            H, Dh = cfg.n_heads, cfg.head_dim
            z = common.apply_norm("layernorm", h, p_l["ln1"])
            k = ctx.linear("dec.attn.wk", z, p_l["attn"]["wk"]).reshape(
                B, 1, H, Dh)
            v = ctx.linear("dec.attn.wv", z, p_l["attn"]["wv"],
                           p_l["attn"]["bv"]).reshape(B, 1, H, Dh)
            if "k_scale" in cache:
                for nm, t in (("k", k), ("v", v)):
                    codes, scl = skv.kv_quantize(t)
                    cache[nm] = jax.lax.dynamic_update_slice(
                        cache[nm], codes[None], (i, 0, pos, 0, 0))
                    cache[f"{nm}_scale"] = jax.lax.dynamic_update_slice(
                        cache[f"{nm}_scale"], scl[None], (i, 0, pos, 0, 0))
                self_names = ("k", "k_scale", "v", "v_scale")
                cross_names = ("xk", "xk_scale", "xv", "xv_scale")
            else:
                cache["k"] = jax.lax.dynamic_update_slice(
                    cache["k"], k[None].astype(cache["k"].dtype),
                    (i, 0, pos, 0, 0))
                cache["v"] = jax.lax.dynamic_update_slice(
                    cache["v"], v[None].astype(cache["v"].dtype),
                    (i, 0, pos, 0, 0))
                self_names = ("k", "v")
                cross_names = ("xk", "xv")
            self_kv = tuple(
                jax.lax.dynamic_index_in_dim(cache[nm], i, 0, False)
                for nm in self_names)
            cross_kv = tuple(
                jax.lax.dynamic_index_in_dim(cache[nm], i, 0, False)
                for nm in cross_names)
            h = self._dec_layer(p_l, h, None, ctx, "dec", self_kv=self_kv,
                                cross_kv=cross_kv, pos=pos)
            return (h, cache), None

        n = cfg.n_layers
        (x, cache), _ = common.scan_layers(body, (x, cache),
                                           params["dec_layers"], jnp.arange(n))
        x = common.apply_norm("layernorm", x, params["dec_norm"])
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits, cache

    # ---------------------------------------------------------- PTQ plan
    def quant_blocks(self, params, batch_tokens, frames):
        """Quantizes decoder layers (the generation path); encoder layers are
        quantized with the same machinery by treating enc as preprocessing."""
        cfg = self.cfg
        ctx = QuantCtx(mode="fp")
        enc_out = self.encode(params, frames, ctx)
        x0 = common.embed_tokens(params["embed"], batch_tokens)
        x0 = x0 + _sinusoid(batch_tokens.shape[1],
                            cfg.d_model).astype(x0.dtype)[None]
        a_names = ["wq", "wk", "wv", "wo"]
        blocks = []
        # fresh per call: the decoder apply closures bake this call's enc_out
        call_token = object()
        for i in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a: a[i], params["dec_layers"])
            name = f"layers.{i}"  # canonical "layers.<i>.<site>" naming
            sites = {}
            for n in a_names:
                sites[f"{name}.attn.{n}"] = Site(("attn", n))
                sites[f"{name}.xattn.{n}"] = Site(("xattn", n))
            for n in ("w_up", "w_down"):
                sites[f"{name}.mlp.{n}"] = Site(("mlp", n))

            def apply_fn(p, x, ctx, _n=name):
                return self._dec_layer(p, x, enc_out, ctx, _n)

            blocks.append(BlockHandle(name, p_l, apply_fn, sites,
                                      apply_key=(call_token,)))

        def assemble(finalized):
            out = dict(params)
            out["dec_layers"] = common.stack_layers(finalized)
            return out

        return x0, blocks, assemble


def _sinusoid_at(pos, D: int) -> jax.Array:
    dim = jnp.arange(D // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000.0 ** (2 * dim / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
