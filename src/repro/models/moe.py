"""Mixture-of-Experts FFN (GShard/Switch-style einsum dispatch).

Capacity-based top-k routing with group-local position assignment:
tokens are viewed as (G groups, N tokens) so the dispatch tensor
(G, N, E, C) stays O(T * N * k * cf) bytes globally — ``moe_group``
controls N and is chosen per-config so the per-chip share is small.

Sharding: group axis -> data mesh axis, expert axis -> model mesh axis
(deepseek's 256 experts additionally split over data; see launch/sharding).
Router weights stay full-precision (tiny + accuracy-critical); expert
weights are quantizable through ctx.linear with batch_dims=1 (per-expert
FlexRound scales, paper Eq. 2 applied expert-wise). In deploy mode the
stacked (E, d_in, d_out) QTensor experts dispatch to the grid-extended
per-expert dequant-matmul kernel (kernels/dequant_matmul_w4) — the expert
stack is never dequantized to HBM at serving time.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.context import QuantCtx
from repro.models import common


def moe_params(key, cfg, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": jax.random.normal(k1, (D, E), jnp.float32) * (D**-0.5),
        "experts": common.mlp_params(k2, D, F, cfg.act, dtype, lead=(E,)),
    }
    if cfg.n_shared_experts:
        p["shared"] = common.mlp_params(
            k3, D, F * cfg.n_shared_experts, cfg.act, dtype)
    return p


def _capacity(n: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(n * top_k * factor / n_experts)
    return max(4, ((c + 3) // 4) * 4)


def _pick_group(tokens: int, target: int) -> int:
    """Largest divisor of ``tokens`` that is <= target (group size)."""
    for n in range(target, 0, -1):
        if tokens % n == 0:
            return n
    return 1


def moe_ffn(p: dict, x: jax.Array, cfg, ctx: QuantCtx, name: str) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    N = _pick_group(T, min(cfg.moe_group, T))
    G = T // N
    C = _capacity(N, K, E, cfg.capacity_factor)

    # groups ride the data axes; experts ride the model axis (EP). Without
    # these hints GSPMD falls back to "involuntary full rematerialization"
    # (observed: replicating the (G,N,D) stream per layer — see EXPERIMENTS.md
    # §Perf deepseek iteration 1).
    xt = common.shard_hint(x.reshape(G, N, D), "dp", None, None)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G,N,E)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (G,N,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)  # renormalize top-k

    counts = jnp.zeros((G, E), jnp.int32)
    dispatch = jnp.zeros((G, N, E, C), jnp.float32)
    combine = jnp.zeros((G, N, E, C), jnp.float32)
    for j in range(K):  # K is small and static (1..8)
        onehot = jax.nn.one_hot(idx[..., j], E, dtype=jnp.int32)  # (G,N,E)
        pos = counts[:, None, :] + jnp.cumsum(onehot, axis=1) - onehot
        within = (pos < C) & (onehot > 0)
        pos_oh = jax.nn.one_hot(jnp.where(within, pos, C), C, dtype=jnp.float32)
        d_j = jnp.where(within[..., None], pos_oh, 0.0)  # (G,N,E,C)
        dispatch = dispatch + d_j
        combine = combine + d_j * gate_vals[..., j][..., None, None]
        counts = counts + jnp.sum(onehot, axis=1)

    xd = x.dtype
    # expert axis placement must match the weight sharding: full EP (one
    # expert per chip over data*model) when divisible, else EP over model
    e_axes = "model"
    mesh = common.get_ambient_mesh()
    if mesh is not None:
        names = set(mesh.axis_names)
        full = (mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
                if "data" in names or "model" in names else 1)
        if full > 1 and E % full == 0:
            e_axes = ("data", "model")
    # Under full EP the expert buffers give up the group sharding and take
    # E over (data, model) — the dispatch einsum becomes the all-to-all.
    # (Measured iteration log in EXPERIMENTS.md §Perf: keeping the masks
    # E-sharded too is what minimizes peak; a chunked-dispatch variant is the
    # recorded next step for the remaining prefill transient.)
    g_e = None if isinstance(e_axes, tuple) else "dp"
    dispatch = common.shard_hint(dispatch, g_e, None, e_axes, None)
    combine = common.shard_hint(combine, g_e, None, e_axes, None)
    xe = jnp.einsum("gnec,gnd->gecd", dispatch.astype(xd), xt)  # (G,E,C,D)
    xe = common.shard_hint(xe, g_e, e_axes, None, None)
    ye = common.mlp(p["experts"], xe, ctx, f"{name}.experts", cfg.act,
                    batch_dims=1)
    ye = common.shard_hint(ye, g_e, e_axes, None, None)
    y = jnp.einsum("gnec,gecd->gnd", combine.astype(xd), ye)
    y = common.shard_hint(y, "dp", None, None).reshape(B, S, D)

    if "shared" in p:
        y = y + common.mlp(p["shared"], x, ctx, f"{name}.shared", cfg.act)

    # auxiliary load-balance loss (Switch eq. 4), returned via ctx-free pair
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    fe = jnp.mean(dispatch.sum(-1), axis=(0, 1))  # fraction dispatched
    aux = E * jnp.sum(me * fe)
    return y, aux


def moe_sites(prefix: str, cfg) -> dict:
    """Quantizable leaves for one MoE layer (used by quant_plan)."""
    from repro.core.reconstruct import Site
    base = ("mlp", "experts")
    names = ["w_up", "w_down"] + (["w_gate"] if cfg.act == "swiglu" else [])
    sites = {f"{prefix}.experts.{n}": Site(base + (n,), batch_dims=1)
             for n in names}
    if cfg.n_shared_experts:
        sites.update({f"{prefix}.shared.{n}": Site(("mlp", "shared", n))
                      for n in names})
    return sites
