"""Model registry: family -> implementation class."""
from __future__ import annotations

from repro.models.encdec import EncDecLM
from repro.models.rglru import GriffinLM
from repro.models.ssm import MambaLM
from repro.models.transformer import TransformerLM


def build_model(cfg):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return GriffinLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
