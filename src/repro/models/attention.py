"""Attention primitives: chunked online-softmax (train/prefill), decode.

All variants support GQA (n_kv_heads <= n_heads), causal or bidirectional
masking, and local (sliding-window) attention. The chunked path is the
memory-efficient Rabe–Staats/flash pattern expressed in pure XLA ops — it
scans over KV chunks with a running (max, sum, acc) so the (Sq, Sk) score
matrix is never materialized beyond one chunk. This is what the multi-pod
dry-run lowers; the Pallas kernels are the TPU-executable analogue.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q (B,Sq,Hq,D), k (B,Sk,Hkv,D) -> scores (B,Hkv,G,Sq,Sk)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                      k.astype(jnp.float32))


def _gqa_out(probs, v):
    """probs (B,Hkv,G,Sq,Sk), v (B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    B, Hkv, G, Sq, Sk = probs.shape
    D = v.shape[-1]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hkv * G, D)


def _mask(q_pos, k_pos, causal: bool, window: int):
    """(Sq, Sk) boolean validity mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, q_offset: int = 0, chunk: int = 1024,
              kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Chunked online-softmax attention.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). ``q_offset`` is the absolute
    position of q[0] (prefill continuation). ``kv_len`` optionally masks the
    valid prefix of k/v (decode against a partially-filled cache).
    Returns (B, Sq, Hq, D) in q.dtype.

    Causal self-attention skips fully-masked KV blocks by chunking queries
    and truncating each query chunk's KV to its causal prefix — ~2x fewer
    attention FLOPs at long sequence (§Perf iteration "causal-qchunk").
    """
    Sq, Sk = q.shape[1], k.shape[1]
    if (causal and window == 0 and q_offset == 0 and Sq == Sk
            and kv_len is None and chunk < Sq and Sq % chunk == 0):
        # at most 4 query chunks: captures most of the causal-skip win
        # ((n+1)/2n flops) without unrolling long chains of inner scans.
        # At very long Sq the k[:, :hi] slices cost transient KV copies, so
        # fall back to 2 chunks (still 75% -> 25% saved).
        n_q = 4 if Sq <= 8192 else 2
        qchunk = max(chunk, Sq // n_q)
        outs = []
        for i in range(Sq // qchunk):
            hi = (i + 1) * qchunk
            outs.append(_attention_inner(
                q[:, i * qchunk:hi], k[:, :hi], v[:, :hi], causal=True,
                window=0, q_offset=i * qchunk, chunk=chunk, kv_len=None))
        return jnp.concatenate(outs, axis=1)
    return _attention_inner(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, chunk=chunk, kv_len=kv_len)


def _attention_inner(q, k, v, *, causal, window, q_offset, chunk, kv_len):
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    Dv = v.shape[-1]  # may differ from D (MLA: qk_dim != v_dim)
    G = Hq // Hkv
    scale = D**-0.5
    chunk = min(chunk, Sk)
    if Sk % chunk:  # pad KV to a chunk multiple; padded keys masked by kv_len
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = jnp.minimum(kv_len, Sk) if kv_len is not None else Sk
        Sk = Sk + pad
    n_chunks = Sk // chunk

    q_pos = q_offset + jnp.arange(Sq)
    kc = k.reshape(B, n_chunks, chunk, Hkv, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dv).swapaxes(0, 1)

    def body(carry, inp):
        m_run, l_run, acc = carry
        kb, vb, idx = inp
        k_pos = idx * chunk + jnp.arange(chunk)
        s = _gqa_scores(q, kb) * scale  # (B,Hkv,G,Sq,chunk)
        valid = _mask(q_pos, k_pos, causal, window)
        if kv_len is not None:
            valid = valid & (k_pos[None, :] < kv_len)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,G,Sq,Dv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv)
    return out.astype(q.dtype)


def attention_full(q, k, v, *, causal=True, window=0, q_offset=0,
                   kv_len=None) -> jax.Array:
    """Reference O(Sq*Sk)-memory attention (oracle for tests/small shapes)."""
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    scale = D**-0.5
    s = _gqa_scores(q, k) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    valid = _mask(q_pos, k_pos, causal, window)
    if kv_len is not None:
        valid = valid & (k_pos[None, :] < kv_len)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0) -> jax.Array:
    """Single-token decode: q (B,1,Hq,D) vs cache (B,Smax,Hkv,D).

    ``pos`` is the index of the current token (cache holds pos+1 valid
    entries including the freshly-inserted one) — a scalar for a uniform
    batch, or (B,) when each row sits at its own depth (the serving
    engine's slot-based decode).
    """
    B, _, Hq, D = q.shape
    Smax = k_cache.shape[1]
    scale = D**-0.5
    s = _gqa_scores(q, k_cache) * scale  # (B,Hkv,G,1,Smax)
    k_pos = jnp.arange(Smax)
    pos = jnp.asarray(pos)
    posb = jnp.broadcast_to(pos.reshape(-1, 1) if pos.ndim else pos, (B, 1))
    valid = k_pos[None, :] <= posb  # (B, Smax)
    if window > 0:
        valid &= k_pos[None, :] > posb - window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v_cache).astype(q.dtype)
