"""Shared model components: norms, RoPE, MLPs, embeddings, fused loss."""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.context import QuantCtx


# ------------------------------------------------------- layer stacks / scan
def stack_layers(layers):
    """Restack per-layer param trees into (L, ...) arrays for ``lax.scan``.

    Mixed-precision PTQ can finalize different layers to structurally
    different trees (QTensor carries static bits/packing in its treedef) or
    to same-treedef trees with different leaf shapes (e.g. a per-channel
    granularity rule on one layer), so when the layers are heterogeneous in
    either way this falls back to a plain list — consumed by the
    eager-unroll path of ``scan_layers``. QTensor leaves in either form hit
    the kernel-backed deploy matmuls via ``ctx.linear`` (the unrolled layers
    each dispatch their own bit-width to the matching kernel).
    """
    same_tree = len({jax.tree.structure(l) for l in layers}) == 1
    if same_tree and len({tuple(jnp.shape(x) for x in jax.tree.leaves(l))
                          for l in layers}) == 1:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return list(layers)


def scan_layers(body, carry, layers, *aux):
    """``lax.scan`` body over per-layer params, with a mixed-precision path.

    ``body(carry, p_l) -> (carry, out)`` — or ``body(carry, (p_l, *aux_l))``
    when ``aux`` (stacked (L, ...) arrays sliced per layer) is given. When
    ``layers`` is a stacked pytree this is exactly ``lax.scan``; when it is a
    list of heterogeneous per-layer trees the loop unrolls eagerly (bigger
    HLO, same math).
    """
    if isinstance(layers, (list, tuple)):
        outs = []
        for i, p_l in enumerate(layers):
            aux_l = tuple(jax.tree.map(lambda a: a[i], a_) for a_ in aux)
            carry, out = body(carry, (p_l, *aux_l) if aux else p_l)
            outs.append(out)
        if not outs or all(o is None for o in outs):
            return carry, None
        return carry, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return jax.lax.scan(body, carry, (layers, *aux) if aux else layers)


# ------------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))  # gamma stored zero-centered
    return y.astype(x.dtype)


def layernorm(x: jax.Array, scale: Optional[jax.Array], bias: Optional[jax.Array],
              eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(kind: str, x: jax.Array, p: Optional[dict]) -> jax.Array:
    """kind: rmsnorm | layernorm | layernorm_nonparam (OLMo)."""
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"] if p else None)
    if kind == "layernorm":
        return layernorm(x, p.get("scale") if p else None,
                         p.get("bias") if p else None)
    if kind == "layernorm_nonparam":
        return layernorm(x, None, None)
    raise ValueError(f"unknown norm {kind!r}")


def norm_params(kind: str, d: int, dtype) -> Optional[dict]:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}  # gamma, applied as (1+gamma)
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "layernorm_nonparam":
        return None
    raise ValueError(kind)


# -------------------------------------------------------------------- rope
def rope_sin_cos(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) int -> sin/cos (..., head_dim/2) in float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, S, H, D); sin/cos: (B or 1, S, D/2). Rotate-half convention."""
    x32 = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x32[..., :half], x32[..., half:]
    s = sin[:, :, None, :]
    c = cos[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- mlp
def mlp(p: dict, x: jax.Array, ctx: QuantCtx, name: str, act: str = "swiglu",
        batch_dims: int = 0) -> jax.Array:
    """SwiGLU or GELU MLP; all matmuls quantizable via ctx."""
    if act in ("swiglu", "geglu"):
        g = ctx.linear(f"{name}.w_gate", x, p["w_gate"], batch_dims=batch_dims)
        u = ctx.linear(f"{name}.w_up", x, p["w_up"], batch_dims=batch_dims)
        nl = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = nl(g.astype(jnp.float32)).astype(x.dtype) * u
    elif act == "gelu":
        h = ctx.linear(f"{name}.w_up", x, p["w_up"], p.get("b_up"),
                       batch_dims=batch_dims)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(f"unknown act {act!r}")
    return ctx.linear(f"{name}.w_down", h, p["w_down"], p.get("b_down"),
                      batch_dims=batch_dims)


def mlp_params(key, d_model: int, d_ff: int, act: str, dtype,
               lead: tuple = ()) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = d_model**-0.5
    std_out = d_ff**-0.5
    p = {
        "w_up": jax.random.normal(k1, lead + (d_model, d_ff), dtype) * std_in,
        "w_down": jax.random.normal(k2, lead + (d_ff, d_model), dtype) * std_out,
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k3, lead + (d_model, d_ff), dtype) * std_in
    return p


# ------------------------------------------------------------------ loss
def fused_cross_entropy(x: jax.Array, w_out: jax.Array, labels: jax.Array,
                        mask: Optional[jax.Array] = None,
                        chunk: int = 512, logit_scale: float = 1.0) -> jax.Array:
    """Mean next-token CE without materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk is rematerialized in the backward
    pass (jax.checkpoint), so peak memory is O(B * chunk * V) instead of
    O(B * S * V) — required for train_4k at 152k-256k vocabularies.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk
    if rem:  # fold remainder into one extra masked chunk via padding
        pad = chunk - rem
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None else
                       jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
        n_chunks += 1
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    xc = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_loss(xb, lb, mb):
        logits = (xb.astype(jnp.float32) @ w_out.astype(jnp.float32)) * logit_scale
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mb), jnp.sum(mb)

    def body(carry, inp):
        tot, cnt = carry
        l, c = chunk_loss(*inp)
        return (tot + l, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def embed_tokens(embed: jax.Array, tokens: jax.Array, mult: float = 1.0) -> jax.Array:
    return jnp.take(embed, tokens, axis=0) * mult


_AMBIENT_MESH = [None]


@contextlib.contextmanager
def ambient_mesh(mesh):
    """Make the physical mesh visible to model-internal sharding hints.

    (The Auto-axis mesh context does not populate get_abstract_mesh inside
    jit tracing — verified on jax 0.8 — so hints need the concrete mesh.)
    """
    _AMBIENT_MESH.append(mesh)
    try:
        yield
    finally:
        _AMBIENT_MESH.pop()


def get_ambient_mesh():
    return _AMBIENT_MESH[-1]


def shard_hint(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint that no-ops without an ambient mesh.

    ``axes`` entries: None, a mesh axis name, "dp" (expands to the data(/pod)
    axes present), or a tuple of names. Axes missing from the ambient mesh
    degrade to None, so the same model code runs on CPU tests and under the
    production mesh. Indivisible dims degrade to None per-axis.
    """
    mesh = get_ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def size_of(a):
        n = 1
        for nm in (a if isinstance(a, tuple) else (a,)):
            n *= mesh.shape[nm]
        return n

    spec = []
    for dim, a in zip(x.shape, axes):
        if a == "dp":
            a = tuple(n for n in ("pod", "data") if n in names)
        elif isinstance(a, str):
            a = (a,) if a in names else ()
        elif isinstance(a, tuple):
            a = tuple(n for n in a if n in names)
        elif a is None:
            a = ()
        a = tuple(a)
        if not a or dim % size_of(a) != 0:
            spec.append(None)
        else:
            spec.append(a if len(a) > 1 else a[0])
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*spec)))
