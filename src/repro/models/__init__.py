from repro.models.model import build_model  # noqa: F401
