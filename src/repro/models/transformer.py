"""Decoder-only transformer LM covering the dense / moe / vlm families
(qwen2.5, smollm, granite, olmo, llama4-scout, deepseek-v3, phi3-vision).

Layers are stored stacked (L, ...) and executed with lax.scan (+ optional
jax.checkpoint) so HLO stays small even for the 61-layer/671B dry-run config.
Heterogeneous stacks (deepseek's leading dense layers) use two scans.

Every matmul routes through QuantCtx so the same code runs fp pretraining,
PTQ reconstruction, and int-weight serving.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.context import QuantCtx
from repro.core.reconstruct import BlockHandle, Site
from repro.models import attention as attn
from repro.models import common, mla, moe
from repro.serve import kv as skv

MTP_WEIGHT = 0.3

# promoted to repro.serve.kv (shared with encdec + the serving engine);
# kept as module aliases for callers of the original private names
_kv_quantize = skv.kv_quantize
_kv_dequantize = skv.kv_dequantize


def _cache_write(buf, li, pos, val):
    """Insert one token's (B, 1, ...) entry into layer ``li`` of a
    (L, B, Smax, ...) cache at ``pos`` — a scalar (uniform batch) or (B,)
    (serving slots, each at its own depth)."""
    val = val.astype(buf.dtype)
    pos = jnp.asarray(pos)
    if pos.ndim:
        return buf.at[li, jnp.arange(val.shape[0]), pos].set(val[:, 0])
    return jax.lax.dynamic_update_slice(
        buf, val[None], (li, 0, pos) + (0,) * (buf.ndim - 3))


# ----------------------------------------------------------------- params
def _attn_params(key, cfg, dtype) -> dict:
    if cfg.use_mla:
        return mla.mla_params(key, cfg, dtype)
    ks = jax.random.split(key, 4)
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = D**-0.5
    p = {
        "wq": jax.random.normal(ks[0], (D, H * Dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (D, Hkv * Dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (D, Hkv * Dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (H * Dh, D), dtype) * (H * Dh) ** -0.5,
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((Hkv * Dh,), dtype)
        p["bv"] = jnp.zeros((Hkv * Dh,), dtype)
    return p


def _layer_params(key, cfg, dtype, kind: str) -> dict:
    """kind: dense | moe."""
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": common.norm_params(cfg.norm, cfg.d_model, dtype),
        "attn": _attn_params(k1, cfg, dtype),
        "ln2": common.norm_params(cfg.norm, cfg.d_model, dtype),
    }
    if kind == "moe":
        p["mlp"] = moe.moe_params(k2, cfg, dtype)
    else:
        p["mlp"] = common.mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return {k: v for k, v in p.items() if v is not None}


def _stacked(key, cfg, dtype, kind: str, n: int) -> dict:
    """Stacked (n, ...) layer params with independent per-layer randomness
    (vmap over keys keeps this eval_shape-safe for the dry-run)."""
    ks = jax.random.split(key, n)
    return jax.vmap(lambda k: _layer_params(k, cfg, dtype, kind))(ks)


class TransformerLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------- init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 6)
        params: Dict[str, Any] = {
            "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), dtype) * 0.02,
            "final_norm": common.norm_params(cfg.norm, cfg.d_model, dtype),
        }
        if params["final_norm"] is None:
            del params["final_norm"]
        n_moe = cfg.n_layers - cfg.first_dense
        if cfg.is_moe:
            if cfg.first_dense:
                params["dense_layers"] = _stacked(ks[1], cfg, dtype, "dense",
                                                  cfg.first_dense)
            params["layers"] = _stacked(ks[2], cfg, dtype, "moe", n_moe)
        else:
            params["layers"] = _stacked(ks[2], cfg, dtype, "dense", cfg.n_layers)
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(ks[3], (cfg.d_model, cfg.vocab), dtype)
                * cfg.d_model**-0.5)
        if cfg.mtp:
            params["mtp"] = {
                "proj": jax.random.normal(ks[4], (2 * cfg.d_model, cfg.d_model),
                                          dtype) * (2 * cfg.d_model) ** -0.5,
                "layer": _layer_params(ks[5], cfg, dtype,
                                       "moe" if cfg.is_moe else "dense"),
                "norm": common.norm_params("rmsnorm", cfg.d_model, dtype),
            }
        return params

    # ------------------------------------------------------------ layers
    def _attn_full(self, p, x, ctx, name, sin, cos):
        cfg = self.cfg
        if cfg.use_mla:
            out, kv = mla.mla_forward(p["attn"], x, cfg, ctx, name, sin, cos)
            return out, kv
        B, S, _ = x.shape
        H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        a = p["attn"]
        q = ctx.linear(f"{name}.wq", x, a["wq"], a.get("bq")).reshape(B, S, H, Dh)
        k = ctx.linear(f"{name}.wk", x, a["wk"], a.get("bk")).reshape(B, S, Hkv, Dh)
        v = ctx.linear(f"{name}.wv", x, a["wv"], a.get("bv")).reshape(B, S, Hkv, Dh)
        q = common.apply_rope(q, sin, cos)
        k = common.apply_rope(k, sin, cos)
        o = attn.attention(q, k, v, causal=True, window=cfg.local_window,
                           chunk=cfg.attn_chunk)
        return ctx.linear(f"{name}.wo", o.reshape(B, S, H * Dh), a["wo"]), (k, v)

    def layer_apply(self, p, x, ctx, name, sin, cos, kind: str):
        """Full-sequence layer; returns (y, aux_loss, kv)."""
        cfg = self.cfg
        h = common.apply_norm(cfg.norm, x, p.get("ln1"))
        a_out, kv = self._attn_full(p, h, ctx, name, sin, cos)
        x = x + a_out * cfg.resid_mult
        h = common.apply_norm(cfg.norm, x, p.get("ln2"))
        if kind == "moe":
            m_out, aux = moe.moe_ffn(p["mlp"], h, cfg, ctx, name)
        else:
            m_out = common.mlp(p["mlp"], h, ctx, f"{name}.mlp", cfg.act)
            aux = jnp.float32(0.0)
        x = x + m_out * cfg.resid_mult
        return x, aux, kv

    def _scan_layers(self, stacked, x, ctx, sin, cos, kind, name,
                     collect_kv=False):
        cfg = self.cfg

        def body(carry, p_l):
            h, aux = carry
            y, a, kv = self.layer_apply(p_l, h, ctx, name, sin, cos, kind)
            out = kv if collect_kv else None
            return (y, aux + a), out

        if cfg.remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), kvs = common.scan_layers(body, (x, jnp.float32(0.0)), stacked)
        return x, aux, kvs

    # ----------------------------------------------------------- forward
    def backbone(self, params, tokens, ctx, extra_embeds=None,
                 collect_kv=False):
        """tokens (B,S) [+ optional (B,P,D) prefix embeds] -> hidden (B,S',D)."""
        cfg = self.cfg
        x = common.embed_tokens(params["embed"], tokens, cfg.emb_mult)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        sin, cos = common.rope_sin_cos(
            pos, cfg.qk_rope_dim if cfg.use_mla else cfg.head_dim,
            cfg.rope_theta)
        aux = jnp.float32(0.0)
        kvs = []
        if "dense_layers" in params:
            x, a, kv = self._scan_layers(params["dense_layers"], x, ctx, sin,
                                         cos, "dense", "dense", collect_kv)
            aux += a
            kvs.append(kv)
        kind = "moe" if cfg.is_moe else "dense"
        x, a, kv = self._scan_layers(params["layers"], x, ctx, sin, cos, kind,
                                     "layers", collect_kv)
        aux += a
        kvs.append(kv)
        x = common.apply_norm(cfg.norm, x, params.get("final_norm"))
        return x, aux, kvs

    def lm_head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def loss(self, params, batch, ctx) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, aux, _ = self.backbone(params, batch["tokens"], ctx,
                                  batch.get("patch_embeds"))
        mask = batch.get("mask")
        labels = batch["labels"]
        if batch.get("patch_embeds") is not None:
            P = batch["patch_embeds"].shape[1]
            labels = jnp.pad(labels, ((0, 0), (P, 0)))
            m = jnp.pad(mask if mask is not None else
                        jnp.ones_like(batch["labels"], jnp.float32),
                        ((0, 0), (P, 0)))
            mask = m.at[:, :P].set(0.0)
        ce = common.fused_cross_entropy(x, self.lm_head(params), labels, mask,
                                        cfg.xent_chunk, cfg.logit_mult)
        metrics = {"ce": ce, "aux": aux}
        total = ce + 0.01 * aux
        if cfg.mtp:
            mtp_ce = self._mtp_loss(params, x, batch, ctx)
            metrics["mtp_ce"] = mtp_ce
            total = total + MTP_WEIGHT * mtp_ce
        return total, metrics

    def _mtp_loss(self, params, h, batch, ctx):
        """DeepSeek-style 1-depth multi-token prediction: predict t+2 from
        [h_t ; emb(t+1)] through one extra block and the shared head."""
        cfg = self.cfg
        m = params["mtp"]
        tokens = batch["tokens"]
        emb_next = common.embed_tokens(params["embed"], tokens, cfg.emb_mult)
        # align: h[:, :-1] with emb of tokens[:, 1:]
        cat = jnp.concatenate([h[:, :-1], emb_next[:, 1:]], axis=-1)
        z = ctx.linear("mtp.proj", cat, m["proj"])
        z = common.rmsnorm(z, m["norm"]["scale"])
        B, S, _ = z.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        sin, cos = common.rope_sin_cos(
            pos, cfg.qk_rope_dim if cfg.use_mla else cfg.head_dim,
            cfg.rope_theta)
        z, _, _ = self.layer_apply(m["layer"], z, ctx, "mtp.layer", sin, cos,
                                   "moe" if cfg.is_moe else "dense")
        labels = batch["labels"]
        mtp_labels = jnp.pad(labels[:, 1:], ((0, 0), (0, 0)))  # already +1
        return common.fused_cross_entropy(z, self.lm_head(params), mtp_labels,
                                          None, cfg.xent_chunk, cfg.logit_mult)

    # ------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int, dtype=None,
                   kv_quant: bool = False):
        """kv_quant: int8 per-(token, head) absmax-quantized KV cache —
        halves the decode memory-roofline term (beyond-paper; §Perf)."""
        cfg = self.cfg
        skv.check_kv_quant_supported(cfg, kv_quant)
        dtype = dtype or jnp.dtype(cfg.dtype)
        L = cfg.n_layers
        if cfg.use_mla:
            return {
                "ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dtype),
            }
        kv_shape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        if kv_quant:
            s_shape = (L, batch, max_len, cfg.n_kv_heads, 1)
            return {
                "k": jnp.zeros(kv_shape, jnp.int8),
                "v": jnp.zeros(kv_shape, jnp.int8),
                "k_scale": jnp.zeros(s_shape, jnp.float32),
                "v_scale": jnp.zeros(s_shape, jnp.float32),
            }
        return {"k": jnp.zeros(kv_shape, dtype),
                "v": jnp.zeros(kv_shape, dtype)}

    def _all_layers(self, params):
        """(stacked params over ALL layers, kinds list) concat dense+moe."""
        cfg = self.cfg
        if "dense_layers" in params:
            return [(params["dense_layers"], "dense", cfg.first_dense),
                    (params["layers"], "moe", cfg.n_layers - cfg.first_dense)]
        kind = "moe" if cfg.is_moe else "dense"
        return [(params["layers"], kind, cfg.n_layers)]

    def prefill(self, params, tokens, cache, ctx, extra_embeds=None,
                true_len=None):
        """Run full sequence, fill cache; returns (last hidden, cache).

        ``true_len`` (B,) optionally marks each row's real prompt length
        inside a right-padded bucket: the returned hidden is gathered at
        ``true_len - 1`` instead of the last column. Causal masking makes
        hidden states at real positions bit-identical to an unpadded run
        (padded keys sit strictly in the future of every real query), so
        bucketed prefill costs no accuracy — only the padded FLOPs.
        """
        cfg = self.cfg
        x, _, kvs = self.backbone(params, tokens, ctx, extra_embeds,
                                  collect_kv=True)
        off = 0
        flat_kvs = [kv for kv in kvs if kv is not None]
        for (stack, kind, n), kv in zip(self._all_layers(params), flat_kvs):
            if cfg.use_mla:
                ckv, kr = kv  # (n,B,S,r), (n,B,S,dr)
                cache["ckv"] = jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), (off, 0, 0, 0))
                cache["kr"] = jax.lax.dynamic_update_slice(
                    cache["kr"], kr.astype(cache["kr"].dtype), (off, 0, 0, 0))
            else:
                k, v = kv
                if "k_scale" in cache:
                    for nm, t in (("k", k), ("v", v)):
                        codes, scl = _kv_quantize(t)
                        cache[nm] = jax.lax.dynamic_update_slice(
                            cache[nm], codes, (off, 0, 0, 0, 0))
                        cache[f"{nm}_scale"] = jax.lax.dynamic_update_slice(
                            cache[f"{nm}_scale"], scl, (off, 0, 0, 0, 0))
                else:
                    cache["k"] = jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype),
                        (off, 0, 0, 0, 0))
                    cache["v"] = jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype),
                        (off, 0, 0, 0, 0))
            off += n
        if true_len is not None:
            B = x.shape[0]
            idx = jnp.asarray(true_len, jnp.int32) - 1
            x = x[jnp.arange(B), idx][:, None]
            return x, cache
        return x[:, -1:], cache

    def decode_step(self, params, token, cache, pos, ctx):
        """token (B,1) int32; pos int32 — scalar (uniform batch) or (B,)
        per-row absolute positions (serving slots). Returns
        (logits (B,1,V), cache)."""
        cfg = self.cfg
        pos = jnp.asarray(pos)
        if pos.ndim and cfg.use_mla:
            raise skv.unsupported(
                "mla", f"{cfg.name}: MLA decode takes a uniform scalar "
                "position; slot-based serving is not supported")
        x = common.embed_tokens(params["embed"], token, cfg.emb_mult)
        B = x.shape[0]
        pos_arr = (pos.reshape(B, 1) if pos.ndim
                   else jnp.full((B, 1), pos))
        sin, cos = common.rope_sin_cos(
            pos_arr, cfg.qk_rope_dim if cfg.use_mla else cfg.head_dim,
            cfg.rope_theta)
        off = 0
        for stack, kind, n in self._all_layers(params):
            x, cache = self._decode_scan(stack, x, cache, pos, off, n, kind,
                                         ctx, sin, cos)
            off += n
        x = common.apply_norm(cfg.norm, x, params.get("final_norm"))
        logits = (x @ self.lm_head(params).astype(x.dtype)) * cfg.logit_mult
        return logits, cache

    def _decode_scan(self, stack, x, cache, pos, layer_off, n, kind, ctx,
                     sin, cos):
        cfg = self.cfg

        def body(carry, inp):
            h, cache = carry
            p_l, i = inp
            li = layer_off + i
            z = common.apply_norm(cfg.norm, h, p_l.get("ln1"))
            if cfg.use_mla:
                ckv, kr = mla._kv_latent(p_l["attn"], z, cfg, ctx, "layers",
                                         sin, cos)
                cache["ckv"] = _cache_write(cache["ckv"], li, pos, ckv)
                cache["kr"] = _cache_write(cache["kr"], li, pos, kr)
                a_out = mla.mla_decode(
                    p_l["attn"], z, cfg, ctx, "layers", sin, cos,
                    jax.lax.dynamic_index_in_dim(cache["ckv"], li, 0, False),
                    jax.lax.dynamic_index_in_dim(cache["kr"], li, 0, False),
                    pos)
            else:
                B = z.shape[0]
                H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
                a = p_l["attn"]
                q = ctx.linear("layers.wq", z, a["wq"], a.get("bq")).reshape(
                    B, 1, H, Dh)
                k = ctx.linear("layers.wk", z, a["wk"], a.get("bk")).reshape(
                    B, 1, Hkv, Dh)
                v = ctx.linear("layers.wv", z, a["wv"], a.get("bv")).reshape(
                    B, 1, Hkv, Dh)
                q = common.apply_rope(q, sin, cos)
                k = common.apply_rope(k, sin, cos)
                if "k_scale" in cache:
                    for nm, t in (("k", k), ("v", v)):
                        codes, scl = skv.kv_quantize(t)
                        cache[nm] = _cache_write(cache[nm], li, pos, codes)
                        cache[f"{nm}_scale"] = _cache_write(
                            cache[f"{nm}_scale"], li, pos, scl)
                    layer = [jax.lax.dynamic_index_in_dim(cache[nm], li, 0,
                                                          False)
                             for nm in ("k", "k_scale", "v", "v_scale")]
                    # dequant-free: scales fold in after the contractions,
                    # the cache never rematerializes in k.dtype
                    o = skv.int8_decode_attention(q, *layer, pos,
                                                  window=cfg.local_window)
                else:
                    cache["k"] = _cache_write(cache["k"], li, pos, k)
                    cache["v"] = _cache_write(cache["v"], li, pos, v)
                    k_l = jax.lax.dynamic_index_in_dim(cache["k"], li, 0,
                                                       False)
                    v_l = jax.lax.dynamic_index_in_dim(cache["v"], li, 0,
                                                       False)
                    o = attn.decode_attention(q, k_l, v_l, pos,
                                              window=cfg.local_window)
                a_out = ctx.linear("layers.wo", o.reshape(B, 1, H * Dh),
                                   a["wo"])
            h = h + a_out * cfg.resid_mult
            z = common.apply_norm(cfg.norm, h, p_l.get("ln2"))
            if kind == "moe":
                m_out, _ = moe.moe_ffn(p_l["mlp"], z, cfg, ctx, "layers")
            else:
                m_out = common.mlp(p_l["mlp"], z, ctx, "layers.mlp", cfg.act)
            h = h + m_out * cfg.resid_mult
            return (h, cache), None

        (x, cache), _ = common.scan_layers(body, (x, cache), stack,
                                           jnp.arange(n))
        return x, cache

    # --------------------------------------------------------- PTQ plan
    def _layer_sites(self, kind: str) -> Dict[str, Site]:
        cfg = self.cfg
        sites: Dict[str, Site] = {}
        if cfg.use_mla:
            sites.update(mla.mla_sites("layers", cfg))
        else:
            for n in ("wq", "wk", "wv", "wo"):
                sites[f"layers.{n}"] = Site(("attn", n))
        if kind == "moe":
            sites.update(moe.moe_sites("layers", cfg))
        else:
            names = ["w_up", "w_down"] + (["w_gate"] if cfg.act == "swiglu"
                                          else [])
            sites.update({f"layers.mlp.{n}": Site(("mlp", n)) for n in names})
        return sites

    def quant_blocks(self, params, batch_tokens) -> Tuple[jax.Array, List[BlockHandle], Any]:
        """Returns (x0 hidden stream, per-layer BlockHandles, assemble_fn).

        assemble_fn(finalized_list) -> params with QTensor leaves restacked.
        """
        cfg = self.cfg
        x0 = common.embed_tokens(params["embed"], batch_tokens, cfg.emb_mult)
        B, S = batch_tokens.shape
        # batch-size-1 rope tables broadcast over any recon minibatch size
        pos = jnp.arange(S)[None]
        sin, cos = common.rope_sin_cos(
            pos, cfg.qk_rope_dim if cfg.use_mla else cfg.head_dim,
            cfg.rope_theta)
        blocks = []
        segs = self._all_layers(params)
        gi = 0  # global layer index across segments -> stable site names
        # fresh per call: apply closures bake this call's rope tables, so the
        # compiled-step share group must not leak across quant_blocks calls
        call_token = object()
        for seg_i, (stack, kind, n) in enumerate(segs):
            for i in range(n):
                p_l = jax.tree.map(lambda a: a[i], stack)
                # canonical site naming "layers.<i>.<site>" (shared across
                # model families) so recipe rules like "layers.0.*" are
                # portable; per-layer unique names also keep LSQ activation
                # steps learned per layer (paper's setup), not shared
                bname = f"layers.{gi}"
                gi += 1
                raw_sites = self._layer_sites(kind)
                sites = {k.replace("layers", bname, 1): v
                         for k, v in raw_sites.items()}

                def apply_fn(p, x, ctx, _kind=kind, _bn=bname):
                    y, _, _ = self.layer_apply(p, x, ctx, _bn, sin, cos, _kind)
                    return y

                blocks.append(BlockHandle(name=bname, params=p_l,
                                          apply=apply_fn, sites=sites,
                                          apply_key=(call_token, kind)))

        def assemble(finalized):
            out = dict(params)
            idx = 0
            for seg_i, (stack, kind, n) in enumerate(segs):
                layers = finalized[idx:idx + n]
                idx += n
                key = ("dense_layers" if (seg_i == 0 and len(segs) > 1)
                       else "layers")
                # mixed-precision layers restack to a list (eager unroll)
                out[key] = common.stack_layers(layers)
            return out

        return x0, blocks, assemble
