"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill expand the compressed latent into full per-head K/V; decode uses
the weight-absorbed form so the KV cache is only (kv_lora_rank + qk_rope_dim)
per token — the memory-term win that makes deepseek long-context decode cheap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.context import QuantCtx
from repro.models import attention as attn
from repro.models import common


def mla_params(key, cfg, dtype) -> dict:
    ks = jax.random.split(key, 6)
    D, H = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    s = D**-0.5
    return {
        "wq_a": jax.random.normal(ks[0], (D, rq), dtype) * s,
        "q_norm": common.norm_params("rmsnorm", rq, dtype),
        "wq_b": jax.random.normal(ks[1], (rq, H * (dn + dr)), dtype) * rq**-0.5,
        "wkv_a": jax.random.normal(ks[2], (D, rkv + dr), dtype) * s,
        "kv_norm": common.norm_params("rmsnorm", rkv, dtype),
        "wkv_b": jax.random.normal(ks[3], (rkv, H * (dn + dv)), dtype) * rkv**-0.5,
        "wo": jax.random.normal(ks[4], (H * dv, D), dtype) * (H * dv) ** -0.5,
    }


def _q_proj(p, x, cfg, ctx, name, sin, cos):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = ctx.linear(f"{name}.wq_a", x, p["wq_a"])
    cq = common.rmsnorm(cq, p["q_norm"]["scale"])
    q = ctx.linear(f"{name}.wq_b", cq, p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = common.apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _kv_latent(p, x, cfg, ctx, name, sin, cos):
    rkv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv_full = ctx.linear(f"{name}.wkv_a", x, p["wkv_a"])
    ckv, k_rope = ckv_full[..., :rkv], ckv_full[..., rkv:]
    ckv = common.rmsnorm(ckv, p["kv_norm"]["scale"])
    k_rope = common.apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0]
    return ckv, k_rope


def mla_forward(p, x, cfg, ctx: QuantCtx, name, sin, cos):
    """Full-sequence MLA (train / teacher). Returns (out, (ckv, k_rope))."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _q_proj(p, x, cfg, ctx, name, sin, cos)
    ckv, k_rope = _kv_latent(p, x, cfg, ctx, name, sin, cos)

    kv = ctx.linear(f"{name}.wkv_b", ckv, p["wkv_b"]).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attn.attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    out = ctx.linear(f"{name}.wo", o.reshape(B, S, H * dv), p["wo"])
    return out, (ckv, k_rope)


def mla_decode(p, x, cfg, ctx: QuantCtx, name, sin, cos, ckv_cache, kr_cache,
               pos):
    """Absorbed single-token decode against the latent cache.

    ckv_cache: (B, Smax, rkv) with the current token already inserted;
    kr_cache:  (B, Smax, dr).
    """
    B, _, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    q_nope, q_rope = _q_proj(p, x, cfg, ctx, name, sin, cos)  # (B,1,H,*)

    wkv_b = ctx.get_weight(f"{name}.wkv_b", p["wkv_b"]).reshape(rkv, H, dn + dv)
    w_kb, w_vb = wkv_b[..., :dn], wkv_b[..., dn:]
    # absorb k projection into q: (B,1,H,dn)x(r,H,dn)->(B,1,H,r)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_kb.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv_cache.astype(jnp.float32))
         + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) * scale
    valid = jnp.arange(ckv_cache.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, attn.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", pr, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_vb.astype(jnp.float32))
    out = ctx.linear(f"{name}.wo", o.reshape(B, 1, H * dv).astype(x.dtype),
                     p["wo"])
    return out


def mla_sites(prefix: str, cfg) -> dict:
    from repro.core.reconstruct import Site
    names = ["wq_a", "wq_b", "wkv_a", "wkv_b", "wo"]
    return {f"{prefix}.{n}": Site(("attn", n)) for n in names}
