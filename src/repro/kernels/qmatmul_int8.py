"""Pallas TPU kernel: W8A8 integer matmul (serving path for the paper's
8-bit recipes).

int8 x int8 -> int32 accumulation on the MXU, with the affine corrections
applied on the final K step. For activations a = a_scale * (A_q - a_zero) and
weights b = b_scale * (B_q - b_zero) (b_zero = 0 recovers the symmetric
weight case):

    out = a_scale * b_scale * (A_q @ B_q - a_zero * colsum(B_q)
                               - rowsum(A_q) * b_zero + K * a_zero * b_zero)

colsum/rowsum are computed once outside the kernel on the *unpadded* codes,
so the rank-1 corrections are exact regardless of tile padding. Blocking:
(block_m, block_k) x (block_k, block_n) tiles resident in VMEM, grid
(M/bm, N/bn, K/bk) with an int32 VMEM scratch accumulator; K is the
innermost (sequential) grid axis so the accumulator persists across K steps.
Tile sizes default to MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.envelope import assert_grid_divisible


def _kernel(a_ref, b_ref, ascale_ref, azero_ref, bscale_ref, bzero_ref,
            colsum_ref, rowsum_ref, o_ref, acc_ref, *, k_steps, k_real):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _finish():
        acc = acc_ref[...].astype(jnp.float32)
        a_z = azero_ref[...]
        b_z = bzero_ref[...]  # (1, bn)
        corr = (a_z * colsum_ref[...].astype(jnp.float32)
                + rowsum_ref[...].astype(jnp.float32) * b_z
                - k_real * a_z * b_z)
        o_ref[...] = (ascale_ref[...] * bscale_ref[...] * (acc - corr)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def qmatmul_int8(a_q, b_q, a_scale, a_zero, b_scale, b_zero=None, *,
                 block_m: int = 128, block_n: int = 128, block_k: int = 512,
                 out_dtype=jnp.float32, interpret: bool = False):
    """a_q (M, K) int8, b_q (K, N) int8, b_scale/b_zero (1, N) or (1, 1).
    ``b_zero=None`` means symmetric weights (b = b_scale * b_q)."""
    M, K = a_q.shape
    N = b_q.shape[1]
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    # rank-1 corrections on the unpadded codes (exact under zero padding)
    colsum = jnp.sum(b_q.astype(jnp.int32), axis=0, keepdims=True)  # (1, N)
    rowsum = jnp.sum(a_q.astype(jnp.int32), axis=1, keepdims=True)  # (M, 1)
    # pad every dim to a block multiple: out-of-bounds Pallas tiles are
    # undefined, and zero padding is exact for matmul
    Mp, Kp, Np = (-M % block_m, -K % block_k, -N % block_n)
    a_q = jnp.pad(a_q, ((0, Mp), (0, Kp)))
    b_q = jnp.pad(b_q, ((0, Kp), (0, Np)))
    b_scale = jnp.pad(jnp.broadcast_to(jnp.asarray(b_scale, jnp.float32),
                                       (1, N)), ((0, 0), (0, Np)))
    if b_zero is None:
        b_zero = jnp.zeros((1, N), jnp.float32)
    b_zero = jnp.pad(jnp.broadcast_to(jnp.asarray(b_zero, jnp.float32),
                                      (1, N)), ((0, 0), (0, Np)))
    colsum = jnp.pad(colsum, ((0, 0), (0, Np)))
    rowsum = jnp.pad(rowsum, ((0, Mp), (0, 0)))
    Mf, Kf, Nf = M + Mp, K + Kp, N + Np
    assert_grid_divisible("qmatmul_int8", M=(Mf, block_m), K=(Kf, block_k),
                          N=(Nf, block_n))
    k_steps = pl.cdiv(Kf, block_k)
    a_scale = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (1, 1))
    a_zero = jnp.broadcast_to(jnp.asarray(a_zero, jnp.float32), (1, 1))
    grid = (Mf // block_m, Nf // block_n, k_steps)
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, k_real=float(K)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mf, Nf), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_q, b_q, a_scale, a_zero, b_scale, b_zero, colsum, rowsum)
    return out[:M, :N]
