"""Shape envelopes: the serving contract each kernel-table layout is
verified against.

A :class:`ShapeEnvelope` bounds what the deploy path is allowed to feed a
kernel — shape maxima (tokens per call, contraction size, output width,
expert count) plus value-magnitude bounds for the float operands (activation
magnitude, quantization-grid scale range). The bounds are *contracts*, not
observations: quantcheck (``repro.analysis.intervals``) proves properties
over the whole envelope — e.g. "the int8 x int8 MXU accumulator fits int32
for every K up to ``k_max``" — and the differential verifier
(``repro.analysis.diffcheck``) draws its shape lattice from inside it. A
kernel call outside its envelope is therefore *unverified*, which is exactly
what :func:`check_envelope` makes loud.

Shape maxima are grounded in the model zoo (``repro.configs``): the largest
contraction this repo ever serves is deepseek-v3's d_ff = 18432 (w_down),
the widest output is the 256000-token vocab head, and the deepest expert
stack is 256. Each bound keeps ~2x headroom over those so config growth
does not silently step outside the verified region — raising a bound is an
intentional act that re-runs the proofs against the new region (and QL301
fails the lint if the proof no longer holds).

Grid guards: :func:`assert_grid_divisible` is the explicit divisibility
check every Pallas wrapper runs on its *padded* dims right before building
the grid. Padding makes the guard a tautology today; the guard exists so a
future edit that drops or reorders the padding fails immediately with the
offending dim named, instead of letting Pallas miscompute on ragged tiles
(and so the QL105 AST rule has a structural guard to see).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

INT32_MAX = 2**31 - 1
INT16_MAX = 2**15 - 1
# smallest normal float32: below this, values are subnormal and flush to
# zero on TPU (FTZ) — a scale product down here zeroes gradients through
# FlexRound's reciprocal rule
F32_TINY = 1.1754944e-38


@dataclasses.dataclass(frozen=True)
class ShapeEnvelope:
    """Verified operating region for one kernel-table layout."""
    layout: str            # kernel-table layout name (trace.MATMUL_LAYOUTS)
    m_max: int             # tokens per matmul call (batch * seq)
    k_max: int             # contraction size (d_in)
    n_max: int             # output width (d_out)
    e_max: int = 1         # stacked expert count (batch_dims=1 layouts)
    x_abs_max: float = 64.0    # |activation| bound entering the matmul
    scale_min: float = 1e-12   # quantization-grid scale lower bound
    scale_max: float = 256.0   # quantization-grid scale upper bound
    code_max: int = 255        # largest integer weight code (2^bits - 1)
    seq_max: int = 0           # production sequence window (serve layouts):
    # the memcheck HBM-budget proof (QL401) scales every [*, max_len]
    # buffer traced at smoke scale up to this length, so the smoke trace
    # proves the production window's budget. 0 = no sequence axis.

    def contains(self, m: int, k: int, n: int, e: int = 1) -> bool:
        return (1 <= m <= self.m_max and 1 <= k <= self.k_max
                and 1 <= n <= self.n_max and 1 <= e <= self.e_max)


# Zoo maxima (see repro.configs): K = d_ff 18432, N = vocab 256000,
# E = n_experts 256. m_max bounds prefill batch*seq per call.
_M_MAX = 65536
_K_MAX = 32768
_N_MAX = 524288

SHAPE_ENVELOPES: Dict[str, ShapeEnvelope] = {
    "w4_packed": ShapeEnvelope("w4_packed", _M_MAX, _K_MAX, _N_MAX,
                               code_max=15),
    "w4a8_packed": ShapeEnvelope("w4a8_packed", _M_MAX, _K_MAX, _N_MAX,
                                 code_max=15),
    "w8a8": ShapeEnvelope("w8a8", _M_MAX, _K_MAX, _N_MAX),
    "w8_weight_only": ShapeEnvelope("w8_weight_only", _M_MAX, _K_MAX, _N_MAX),
    "w4_odd_unpacked": ShapeEnvelope("w4_odd_unpacked", _M_MAX, _K_MAX,
                                     _N_MAX, code_max=15),
    "experts_batched": ShapeEnvelope("experts_batched", _M_MAX, _K_MAX,
                                     _N_MAX, e_max=256, code_max=15),
    # the PTQ inner loop's fused fake-quant (not a matmul: m/k/n bound the
    # weight dims, scales bound the learned s1*s2*s3 product factors)
    "flexround_apply": ShapeEnvelope("flexround_apply", _K_MAX, _K_MAX,
                                     _N_MAX, x_abs_max=256.0,
                                     scale_min=1e-6, scale_max=256.0),
    # the serve engine's int8 KV cache (repro.serve.kv): m bounds queries
    # per decode call (slots), k bounds the attention contractions (cached
    # positions x head_dim — max_len dominates), n bounds d_model. The
    # scale floor is kv_quantize's absmax floor KV_EPS/KV_QMAX = 1e-6/127
    # (~7.9e-9, >> F32_TINY, so QL303 proves the stored scales never go
    # subnormal); the ceiling is x_abs_max/127 for activations inside the
    # |x| <= 64 contract.
    "serve_kv": ShapeEnvelope("serve_kv", _M_MAX, 8192, _N_MAX,
                              x_abs_max=64.0, scale_min=1e-6 / 127.0,
                              scale_max=64.0 / 127.0, code_max=127,
                              seq_max=8192),
}


def get_envelope(layout: str) -> ShapeEnvelope:
    try:
        return SHAPE_ENVELOPES[layout]
    except KeyError:
        raise KeyError(
            f"no shape envelope registered for layout {layout!r} — every "
            "kernel-table layout must declare its verified operating region "
            f"(known: {sorted(SHAPE_ENVELOPES)})") from None


def check_envelope(layout: str, m: int, k: int, n: int, e: int = 1) -> None:
    """Raise when a shape leaves the verified region for its layout."""
    env = get_envelope(layout)
    if not env.contains(m, k, n, e):
        raise ValueError(
            f"shape (m={m}, k={k}, n={n}, e={e}) leaves the verified "
            f"envelope of layout {layout!r} (m<={env.m_max}, k<={env.k_max}, "
            f"n<={env.n_max}, e<={env.e_max}) — quantcheck's overflow/parity "
            "proofs do not cover it; widen the envelope and re-run "
            "`python -m repro.analysis.lint` to re-verify")


def assert_grid_divisible(name: str, **dims: Tuple[int, int]) -> None:
    """Explicit grid-divisibility guard for Pallas wrappers.

    ``dims`` maps a dim name to ``(padded_size, block)``; every padded size
    must be an exact block multiple or the grid under-covers the array and
    Pallas silently miscomputes the ragged tail.
    """
    for dim, (size, block) in dims.items():
        if block <= 0 or size % block != 0:
            raise ValueError(
                f"{name}: padded dim {dim}={size} is not a multiple of its "
                f"block {block} — the Pallas grid would drop the ragged "
                "tail; pad to a block multiple before building the grid")
