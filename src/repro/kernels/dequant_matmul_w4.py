"""Pallas TPU kernel: W4A16 dequant-matmul (weight-only int4 serving).

Weights are nibble-packed uint8 (two 4-bit codes per byte along K). Each K
tile is unpacked and dequantized *in VMEM* right before the MXU matmul, so
HBM traffic for the weight is 0.5 bytes/element — the memory-roofline win
that makes int4 decode ~4x lighter than bf16 (see EXPERIMENTS.md §Perf).

    out[M, N] = x[M, K] @ (scale * (unpack(codes)[K, N] - zero))

Grid (M/bm, N/bn, K/bk); float32 VMEM accumulator across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, c_ref, scale_ref, zero_ref, o_ref, acc_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = c_ref[...]  # (bk//2, bn) uint8
    lo = (codes & 0xF).astype(jnp.float32)
    hi = ((codes >> 4) & 0xF).astype(jnp.float32)
    bk2, bn = codes.shape
    q = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)
    w = scale_ref[...] * (q - zero_ref[...])  # dequant in VMEM
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def dequant_matmul_w4(x, codes, scale, zero, *, block_m: int = 128,
                      block_n: int = 128, block_k: int = 512,
                      out_dtype=None, interpret: bool = False):
    """x (M, K); codes (K//2, N) uint8; scale/zero (1, N) or (1, 1)."""
    M, K = x.shape
    N = codes.shape[1]
    assert codes.shape[0] * 2 == K, "codes must be K/2 nibble-packed rows"
    out_dtype = out_dtype or x.dtype
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert block_k % 2 == 0
    # pad to block multiples (zero-padded x rows/K cols contribute nothing)
    Mp, Kp, Np = (-M % block_m, -K % block_k, -N % block_n)
    x = jnp.pad(x, ((0, Mp), (0, Kp)))
    codes = jnp.pad(codes, ((0, Kp // 2), (0, Np)))
    scale = jnp.pad(jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (1, N)),
                    ((0, 0), (0, Np)))
    zero = jnp.pad(jnp.broadcast_to(jnp.asarray(zero, jnp.float32), (1, N)),
                   ((0, 0), (0, Np)))
    Mf, Kf, Nf = M + Mp, K + Kp, N + Np
    k_steps = Kf // block_k
    grid = (Mf // block_m, Nf // block_n, k_steps)
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mf, Nf), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale, zero)
    return out[:M, :N]
