"""Pallas TPU kernels: weight-only dequant-matmul (the int4/int8 serving path).

W4: weights are nibble-packed uint8 (two 4-bit codes per byte along K). Each
K tile is unpacked and dequantized *in VMEM* right before the MXU matmul, so
HBM traffic for the weight is 0.5 bytes/element — the memory-roofline win
that makes int4 decode ~4x lighter than bf16 (see EXPERIMENTS.md §Perf).
W8 is the same kernel without the unpack (1 byte/element, 2x lighter).

    out[M, N] = x[M, K] @ (scale * (unpack(codes)[K, N] - zero))

Grid (M/bm, N/bn, K/bk); float32 VMEM accumulator across K steps. The
batched-expert variant prepends the expert axis to the grid —
(E, M/bm, N/bn, K/bk) with K innermost so the accumulator stays coherent per
(e, i, j) tile — serving stacked MoE expert weights (E, K, N) without ever
materializing the dequantized stack in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.envelope import assert_grid_divisible


def _unpack_f32(codes):
    """(bk//2, bn) packed uint8 -> (bk, bn) float32 codes, pairs along K."""
    lo = (codes & 0xF).astype(jnp.float32)
    hi = ((codes >> 4) & 0xF).astype(jnp.float32)
    bk2, bn = codes.shape
    return jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)


def _kernel(x_ref, c_ref, scale_ref, zero_ref, o_ref, acc_ref, *, k_steps,
            packed):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = c_ref[...]  # (bk//2, bn) uint8 if packed else (bk, bn)
    q = _unpack_f32(codes) if packed else codes.astype(jnp.float32)
    w = scale_ref[...] * (q - zero_ref[...])  # dequant in VMEM
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_batched(x_ref, c_ref, scale_ref, zero_ref, o_ref, acc_ref, *,
                    k_steps, packed):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = c_ref[0]  # expert-sliced block: (bk//2, bn) or (bk, bn)
    q = _unpack_f32(codes) if packed else codes.astype(jnp.float32)
    w = scale_ref[0] * (q - zero_ref[0])
    x = x_ref[0].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _pad_mkn(x, codes, scale, zero, M, K, N, block_m, block_k, block_n,
             packed, lead=()):
    """Pad every operand to block multiples (zero padding is exact for
    matmul; padded x rows / K columns contribute nothing)."""
    z = ((0, 0),) * len(lead)
    Mp, Kp, Np = (-M % block_m, -K % block_k, -N % block_n)
    x = jnp.pad(x, z + ((0, Mp), (0, Kp)))
    codes = jnp.pad(codes, z + ((0, Kp // 2 if packed else Kp), (0, Np)))
    scale = jnp.pad(jnp.broadcast_to(jnp.asarray(scale, jnp.float32),
                                     lead + (1, N)), z + ((0, 0), (0, Np)))
    zero = jnp.pad(jnp.broadcast_to(jnp.asarray(zero, jnp.float32),
                                    lead + (1, N)), z + ((0, 0), (0, Np)))
    return x, codes, scale, zero, M + Mp, K + Kp, N + Np


@functools.partial(jax.jit, static_argnames=("packed", "block_m", "block_n",
                                             "block_k", "out_dtype",
                                             "interpret"))
def dequant_matmul(x, codes, scale, zero, *, packed: bool,
                   block_m: int = 128, block_n: int = 128, block_k: int = 512,
                   out_dtype=None, interpret: bool = False):
    """x (M, K); codes (K//2, N) packed uint8 or (K, N) uint8;
    scale/zero (1, N) or (1, 1)."""
    M, K = x.shape
    N = codes.shape[1]
    if packed:
        assert codes.shape[0] * 2 == K, "codes must be K/2 nibble-packed rows"
    else:
        assert codes.shape[0] == K
    out_dtype = out_dtype or x.dtype
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert block_k % 2 == 0 or not packed
    x, codes, scale, zero, Mf, Kf, Nf = _pad_mkn(
        x, codes, scale, zero, M, K, N, block_m, block_k, block_n, packed)
    assert_grid_divisible("dequant_matmul", M=(Mf, block_m), K=(Kf, block_k),
                          N=(Nf, block_n))
    k_steps = Kf // block_k
    grid = (Mf // block_m, Nf // block_n, k_steps)
    bkc = block_k // 2 if packed else block_k
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkc, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mf, Nf), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale, zero)
    return out[:M, :N]


def dequant_matmul_w4(x, codes, scale, zero, *, block_m: int = 128,
                      block_n: int = 128, block_k: int = 512,
                      out_dtype=None, interpret: bool = False):
    """x (M, K); codes (K//2, N) uint8; scale/zero (1, N) or (1, 1)."""
    return dequant_matmul(x, codes, scale, zero, packed=True, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          out_dtype=out_dtype, interpret=interpret)


def dequant_matmul_w8(x, codes, scale, zero, *, block_m: int = 128,
                      block_n: int = 128, block_k: int = 512,
                      out_dtype=None, interpret: bool = False):
    """x (M, K); codes (K, N) uint8; scale/zero (1, N) or (1, 1). Weight-only
    int8 serving (no activation states)."""
    return dequant_matmul(x, codes, scale, zero, packed=False,
                          block_m=block_m, block_n=block_n, block_k=block_k,
                          out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("packed", "block_m", "block_n",
                                             "block_k", "out_dtype",
                                             "interpret"))
def dequant_matmul_batched(x, codes, scale, zero, *, packed: bool,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 512, out_dtype=None,
                           interpret: bool = False):
    """Grid-extended per-expert dequant-matmul (MoE serving path).

    x (E, M, K); codes (E, K//2, N) packed uint8 or (E, K, N) uint8;
    scale/zero broadcastable to (E, 1, N). out (E, M, N) = per-expert
    x[e] @ dequant(codes[e]).
    """
    E, M, K = x.shape
    N = codes.shape[-1]
    if packed:
        assert codes.shape[1] * 2 == K
    else:
        assert codes.shape[1] == K
    out_dtype = out_dtype or x.dtype
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert block_k % 2 == 0 or not packed
    x, codes, scale, zero, Mf, Kf, Nf = _pad_mkn(
        x, codes, scale, zero, M, K, N, block_m, block_k, block_n, packed,
        lead=(E,))
    assert_grid_divisible("dequant_matmul_batched", M=(Mf, block_m),
                          K=(Kf, block_k), N=(Nf, block_n))
    k_steps = Kf // block_k
    grid = (E, Mf // block_m, Nf // block_n, k_steps)
    bkc = block_k // 2 if packed else block_k
    out = pl.pallas_call(
        functools.partial(_kernel_batched, k_steps=k_steps, packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bkc, block_n), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, 1, block_n), lambda e, i, j, k: (e, 0, j)),
            pl.BlockSpec((1, 1, block_n), lambda e, i, j, k: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Mf, Nf), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale, zero)
    return out[:, :M, :N]
