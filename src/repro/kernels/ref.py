"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def flexround_quant_ref(w, s1, s2, s3, zero, qmin: int, qmax: int):
    """Fused FlexRound quantize: Ŵ = s1*(clip(round(W/(s1*s2*s3))+z) - z).

    w, s2: (M, N); s1, s3, zero: (1, N) broadcastable (per-channel) or (1, 1).
    """
    w32 = w.astype(jnp.float32)
    q = jnp.round(w32 / (s1 * s2 * s3)) + zero
    q = jnp.clip(q, qmin, qmax)
    return (s1 * (q - zero)).astype(w.dtype)


def qmatmul_int8_ref(a_q, b_q, a_scale, a_zero, b_scale, b_zero=None,
                     out_dtype=jnp.float32):
    """W8A8 integer matmul with affine corrections.

    a_q (M, K) int8 codes of activations:  a = a_scale * (a_q - a_zero)
    b_q (K, N) int8 codes of weights:      b = b_scale * (b_q - b_zero)
    b_scale/b_zero: (1, N) per-out-channel or (1, 1); b_zero=None means
    symmetric weights (b = b_scale * b_q).
    """
    acc = jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32).astype(jnp.float32)
    K = a_q.shape[1]
    colsum = jnp.sum(b_q.astype(jnp.int32), axis=0,
                     keepdims=True).astype(jnp.float32)
    out = acc - a_zero * colsum
    if b_zero is not None:
        rowsum = jnp.sum(a_q.astype(jnp.int32), axis=1,
                         keepdims=True).astype(jnp.float32)
        out = out - rowsum * b_zero + K * a_zero * b_zero
    return (a_scale * b_scale * out).astype(out_dtype)


def _unpack_f32(codes, axis=0):
    from repro.core.qtensor import _unpack_nibbles
    return _unpack_nibbles(codes, axis=axis).astype(jnp.float32)


def dequant_matmul_w4_ref(x, codes, scale, zero, out_dtype=None):
    """W4A16 matmul: x (M, K) bf16 @ dequant(codes) where codes are
    nibble-packed (K//2, N) uint8, scale/zero (1, N) or (1, 1) float32."""
    w = scale * (_unpack_f32(codes) - zero)
    out = jnp.dot(x.astype(jnp.float32), w)
    return out.astype(out_dtype or x.dtype)


def dequant_matmul_w8_ref(x, codes, scale, zero, out_dtype=None):
    """W8A16 weight-only matmul: x (M, K) @ dequant(codes (K, N) uint8)."""
    w = scale * (codes.astype(jnp.float32) - zero)
    out = jnp.dot(x.astype(jnp.float32), w)
    return out.astype(out_dtype or x.dtype)


def dequant_matmul_batched_ref(x, codes, scale, zero, packed: bool,
                               out_dtype=None):
    """Per-expert dequant matmul: x (E, M, K) @ dequant(codes[e]) for each
    expert e. codes (E, K//2, N) packed uint8 or (E, K, N) uint8;
    scale/zero broadcastable to (E, 1, N)."""
    q = _unpack_f32(codes, axis=1) if packed else codes.astype(jnp.float32)
    w = scale * (q - zero)  # (E, K, N)
    out = jnp.einsum("emk,ekn->emn", x.astype(jnp.float32), w)
    return out.astype(out_dtype or x.dtype)
