"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flexround_quant_ref(w, s1, s2, s3, zero, qmin: int, qmax: int):
    """Fused FlexRound quantize: Ŵ = s1*(clip(round(W/(s1*s2*s3))+z) - z).

    w, s2: (M, N); s1, s3, zero: (1, N) broadcastable (per-channel) or (1, 1).
    """
    w32 = w.astype(jnp.float32)
    q = jnp.round(w32 / (s1 * s2 * s3)) + zero
    q = jnp.clip(q, qmin, qmax)
    return (s1 * (q - zero)).astype(w.dtype)


def qmatmul_int8_ref(a_q, b_q, a_scale, a_zero, b_scale, out_dtype=jnp.float32):
    """W8A8 integer matmul.

    a_q (M, K) int8 codes of activations:  a = a_scale * (a_q - a_zero)
    b_q (K, N) int8 codes of weights:      b = b_scale * b_q   (symmetric)
    b_scale: (1, N) per-out-channel or (1, 1).
    """
    acc = jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    colsum = jnp.sum(b_q.astype(jnp.int32), axis=0, keepdims=True)
    out = a_scale * b_scale * (acc.astype(jnp.float32)
                               - a_zero * colsum.astype(jnp.float32))
    return out.astype(out_dtype)


def dequant_matmul_w4_ref(x, codes, scale, zero, out_dtype=None):
    """W4A16 matmul: x (M, K) bf16 @ dequant(codes) where codes are
    nibble-packed (K//2, N) uint8, scale/zero (1, N) or (1, 1) float32."""
    lo = (codes & 0xF).astype(jnp.float32)
    hi = ((codes >> 4) & 0xF).astype(jnp.float32)
    q = jnp.stack([lo, hi], axis=1).reshape(codes.shape[0] * 2, codes.shape[1])
    w = scale * (q - zero)
    out = jnp.dot(x.astype(jnp.float32), w)
    return out.astype(out_dtype or x.dtype)
