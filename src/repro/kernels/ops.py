"""jit'd public wrappers for the Pallas kernels, with QTensor integration
and an XLA fallback (``backend='xla'`` routes to the ref implementation —
used by the dry-run, which compiles for the CPU backend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor
from repro.kernels import ref
from repro.kernels.dequant_matmul_w4 import dequant_matmul_w4
from repro.kernels.flexround_quant import flexround_quant
from repro.kernels.qmatmul_int8 import qmatmul_int8


def flexround_fake_quant(w, state, qcfg, *, interpret: bool = True,
                         backend: str = "pallas"):
    """Kernel-backed equivalent of core.flexround.apply (no STE — forward
    only; the training path keeps the jnp version for autodiff)."""
    s1 = jnp.broadcast_to(state["s1"].astype(jnp.float32), (1, w.shape[-1]))
    s3 = state["s3"].reshape(1, -1) if state["s3"].shape[-1] == w.shape[-1] \
        else jnp.broadcast_to(state["s3"].astype(jnp.float32), (1, w.shape[-1]))
    zero = jnp.broadcast_to(state["zero"].astype(jnp.float32), (1, w.shape[-1]))
    if backend == "xla":
        return ref.flexround_quant_ref(w, s1, state["s2"], s3, zero,
                                       qcfg.qmin, qcfg.qmax)
    return flexround_quant(w, s1, state["s2"], s3, zero, qmin=qcfg.qmin,
                           qmax=qcfg.qmax, interpret=interpret)


def qtensor_matmul(x, qt: QTensor, *, a_state=None, interpret: bool = True,
                   backend: str = "pallas"):
    """x @ dequant(qt) for 2-D QTensors.

    - 4-bit packed weights -> W4A16 dequant-matmul kernel.
    - 8-bit weights + a_state (activation int8 params) -> W8A8 int kernel.
    - 8-bit weights, no a_state -> dequant + bf16 matmul (weight-only int8).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    scale = jnp.broadcast_to(qt.scale, (1, qt.shape[-1])).astype(jnp.float32)
    zero = jnp.broadcast_to(qt.zero, (1, qt.shape[-1])).astype(jnp.float32)
    if qt.packed:
        if backend == "xla":
            out = ref.dequant_matmul_w4_ref(x2, qt.codes, scale, zero)
        else:
            out = dequant_matmul_w4(x2, qt.codes, scale, zero,
                                    interpret=interpret)
    elif a_state is not None:
        # dynamic per-tensor activation quantization to int8
        a_scale, a_zero = a_state
        a_q = jnp.clip(jnp.round(x2.astype(jnp.float32) / a_scale) + a_zero,
                       0, 255) - 128  # shift to signed
        a_q = a_q.astype(jnp.int8)
        b_q = (qt.codes.astype(jnp.int32) - jnp.round(qt.zero).astype(jnp.int32)
               ).astype(jnp.int8)
        if backend == "xla":
            out = ref.qmatmul_int8_ref(a_q, b_q, a_scale, a_zero - 128.0,
                                       scale)
        else:
            out = qmatmul_int8(a_q, b_q, a_scale, a_zero - 128.0, scale,
                               interpret=interpret)
        out = out.astype(x.dtype)
    else:
        from repro.core.qtensor import dequantize_qtensor
        out = x2 @ dequantize_qtensor(qt).astype(x2.dtype)
    return out.reshape(lead + (qt.shape[-1],)).astype(x.dtype)
