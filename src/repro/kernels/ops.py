"""jit'd public wrappers for the Pallas kernels, with QTensor integration
and an XLA fallback.

Backend policy (shared with ``QuantCtx``): callers pass
``backend="auto"|"pallas"|"xla"`` and optionally an explicit ``interpret``
flag; ``resolve_backend`` turns that into a concrete dispatch against the
actual jax backend — compiled Pallas on TPU, and on CPU either the XLA ref
path (``auto``: fast, compiles everywhere) or interpreted Pallas
(``pallas``: bit-exact kernel semantics for parity tests).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor, dequantize_qtensor
from repro.kernels import ref
from repro.kernels.dequant_matmul_w4 import (dequant_matmul_batched,
                                             dequant_matmul_w4,
                                             dequant_matmul_w8)
from repro.kernels.flexround_quant import flexround_quant
from repro.kernels.qmatmul_int8 import qmatmul_int8

BACKENDS = ("auto", "pallas", "xla")


def resolve_backend(backend: str = "auto",
                    interpret: Optional[bool] = None) -> Tuple[str, bool]:
    """Resolve a backend request against the actual jax backend.

    Returns ``(backend, interpret)`` with backend in {"pallas", "xla"}:
      - "auto"   -> compiled Pallas on TPU; XLA ref path elsewhere (CPU/GPU
                    production serving should not pay interpret overhead).
      - "pallas" -> Pallas kernels; compiled on TPU, interpret elsewhere
                    (unless ``interpret`` is forced by the caller).
      - "xla"    -> pure-jnp ref implementations (always compile).
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")
    on_tpu = jax.default_backend() == "tpu"
    if backend == "auto":
        backend = "pallas" if on_tpu else "xla"
    if interpret is None:
        interpret = not on_tpu
    return backend, interpret


def _row(v, n: int) -> jax.Array:
    """Normalize a per-tensor (``()``/``(1,1)``) or per-channel
    (``(n,)``/``(1,n)``) parameter to the kernels' (1, n) row layout."""
    v = jnp.asarray(v, jnp.float32)
    if v.size == 1:
        return jnp.broadcast_to(v.reshape(1, 1), (1, n))
    return v.reshape(1, n)


def flexround_fake_quant(w, state, qcfg, *, interpret: Optional[bool] = None,
                         backend: str = "pallas"):
    """Kernel-backed equivalent of core.flexround.apply (no STE — forward
    only; the training path keeps the jnp version for autodiff).

    Accepts the state layouts ``core.flexround.init`` produces: scalar
    per-tensor s1/s3/zero (shape ``()`` or ``(1, 1)``) as well as
    per-output-channel rows ``(1, N)``/``(N,)``.
    """
    n = w.shape[-1]
    s1 = _row(state["s1"], n)
    s3 = _row(state["s3"], n)
    zero = _row(state["zero"], n)
    backend, interpret = resolve_backend(backend, interpret)
    if backend == "xla":
        return ref.flexround_quant_ref(w, s1, state["s2"], s3, zero,
                                       qcfg.qmin, qcfg.qmax)
    return flexround_quant(w, s1, state["s2"], s3, zero, qmin=qcfg.qmin,
                           qmax=qcfg.qmax, interpret=interpret)


def _snap_codes(x2, a_scale, a_zero):
    """Unsigned [0, 255] activation codes on the snapped LSQ deploy grid
    (``lsq.deploy_astate``) — the single source of truth for deploy-mode
    activation quantization; every kernel path derives from it."""
    return jnp.clip(jnp.round(x2.astype(jnp.float32) / a_scale) + a_zero,
                    0, 255)


def _lsq_int8_codes(x2, a_scale, a_zero):
    """Quantize activations to signed int8 codes on the [0, 255] grid."""
    return (_snap_codes(x2, a_scale, a_zero) - 128).astype(jnp.int8)


def _static_act_quant(x2, a_state):
    """LSQ fake-quant of activations on the snapped deploy grid: the same
    [0, 255] integer codes the W8A8 kernel consumes, dequantized back to
    float for the dequant-matmul kernels. This is what keeps W4A8 /
    odd-shape sub-8-bit serving on one deploy grid instead of silently
    dropping the activation quantizer."""
    a_scale, a_zero = a_state
    return (a_scale * (_snap_codes(x2, a_scale, a_zero)
                       - a_zero)).astype(x2.dtype)


def _matmul_2d(x2, qt: QTensor, a_state, backend: str, interpret: bool):
    N = qt.shape[-1]
    scale = _row(qt.scale, N)
    zero = _row(qt.zero, N)
    if qt.packed and qt.pack_axis == 0:
        # W4A8: fake-quant the activations on the static grid, then run the
        # packed dequant kernel (no int4xint8 MXU path — the weight codes
        # are unpacked in VMEM anyway, so the activation grid is the only
        # thing the integer path would add)
        if a_state is not None:
            x2 = _static_act_quant(x2, a_state)
        if backend == "xla":
            return ref.dequant_matmul_w4_ref(x2, qt.codes, scale, zero)
        return dequant_matmul_w4(x2, qt.codes, scale, zero,
                                 interpret=interpret)
    codes = qt.unpacked_codes()  # (K, N) uint8
    if a_state is not None and qt.bits == 8:
        # static activation states: true integer W8A8 matmul. Codes are
        # re-centered at 128 so both operands fit int8; the affine zero
        # offsets become exact rank-1 corrections inside the kernel.
        a_scale, a_zero = a_state
        a_q = _lsq_int8_codes(x2, a_scale, a_zero)
        b_q = (codes.astype(jnp.int32) - 128).astype(jnp.int8)
        b_zero = zero - 128.0
        if backend == "xla":
            out = ref.qmatmul_int8_ref(a_q, b_q, a_scale, a_zero - 128.0,
                                       scale, b_zero=b_zero)
        else:
            out = qmatmul_int8(a_q, b_q, a_scale, a_zero - 128.0, scale,
                               b_zero=b_zero, interpret=interpret)
        return out
    if a_state is not None:
        # sub-8-bit weights that could not nibble-pack: same static
        # activation grid in front of the weight-only kernel
        x2 = _static_act_quant(x2, a_state)
    if backend == "xla":
        return ref.dequant_matmul_w8_ref(x2, codes, scale, zero)
    return dequant_matmul_w8(x2, codes, scale, zero, interpret=interpret)


def _matmul_batched(x3, qt: QTensor, backend: str, interpret: bool):
    """x3 (E, M, K) @ per-expert dequant(qt (E, K, N)) -> (E, M, N)."""
    E, K, N = qt.shape
    scale = jnp.broadcast_to(jnp.asarray(qt.scale, jnp.float32), (E, 1, N))
    zero = jnp.broadcast_to(jnp.asarray(qt.zero, jnp.float32), (E, 1, N))
    packed = qt.packed and qt.pack_axis == 1
    codes = qt.codes if packed else qt.unpacked_codes()
    if backend == "xla":
        return ref.dequant_matmul_batched_ref(x3, codes, scale, zero, packed)
    return dequant_matmul_batched(x3, codes, scale, zero, packed=packed,
                                  interpret=interpret)


def qtensor_matmul(x, qt: QTensor, *, a_state=None, backend: str = "auto",
                   interpret: Optional[bool] = None):
    """x @ dequant(qt) — the deploy-mode serving matmul for every QTensor
    layout. ``a_state`` is the static activation grid ``(a_scale, a_zero)``
    from ``lsq.deploy_astate`` (a_zero the unsigned zero point in [0, 255])
    and is honored on *every* 2-D path, never silently dropped:

    - 4-bit K-packed weights -> W4A16 dequant-matmul kernel; with a_state
      the activations are first fake-quantized on the static grid (W4A8).
    - 8-bit weights + a_state -> W8A8 true-integer kernel.
    - 8-bit weights, no a_state (and <=4-bit weights that could not pack)
      -> W8A16 dequant-matmul kernel (a_state again fake-quantizes first).
    - stacked expert weights (E, K, N) with x (..., E, n, K) -> grid-extended
      per-expert dequant-matmul (activations pre-quantized by the caller).
    """
    backend, interpret = resolve_backend(backend, interpret)
    n_batch = len(qt.shape) - 2
    if n_batch == 0:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        out = _matmul_2d(x2, qt, a_state, backend, interpret)
        return out.reshape(lead + (qt.shape[-1],)).astype(x.dtype)
    if n_batch == 1:
        E, K, N = qt.shape
        n = x.shape[-2]
        lead = x.shape[:-3]
        # (..., E, n, K) -> (E, prod(lead)*n, K)
        x3 = jnp.moveaxis(x.reshape((-1, E, n, K)), 1, 0).reshape(E, -1, K)
        out = _matmul_batched(x3, qt, backend, interpret)
        out = jnp.moveaxis(out.reshape((E, -1, n, N)), 0, 1)
        return out.reshape(lead + (E, n, N)).astype(x.dtype)
    # >1 batch dims: no kernel variant — dequantize (still correct, not fast)
    return (x @ dequantize_qtensor(qt).astype(x.dtype)).astype(x.dtype)
