"""Pallas TPU kernel: fused FlexRound quantize (paper Eq. 2 forward).

The PTQ inner loop evaluates Ŵ = s1*(clip(round(W/(s1⊙S2⊙s3))+z)-z) on the
full weight every iteration — a VPU-bound elementwise chain. Fusing the
divide/round/clip/scale into one VMEM-resident pass avoids 4 HBM round trips
of the (M, N) tensor. Tiles are (block_m, block_n) with block_n a multiple of
128 (lane width) and block_m a multiple of 8 (sublane), the float32 VREG
layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.envelope import assert_grid_divisible


def _kernel(w_ref, s1_ref, s2_ref, s3_ref, z_ref, o_ref, *, qmin, qmax):
    w = w_ref[...].astype(jnp.float32)
    s1 = s1_ref[...]
    div = s1 * s2_ref[...] * s3_ref[...]
    q = jnp.round(w / div) + z_ref[...]
    q = jnp.clip(q, qmin, qmax)
    o_ref[...] = (s1 * (q - z_ref[...])).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "block_m",
                                             "block_n", "interpret"))
def flexround_quant(w, s1, s2, s3, zero, *, qmin: int, qmax: int,
                    block_m: int = 256, block_n: int = 512,
                    interpret: bool = False):
    """w, s2: (M, N); s1/s3/zero: (1, N) or (1, 1) broadcast to (1, N)."""
    M, N = w.shape
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    # pad to block multiples; padded divisors are 1 so no div-by-zero
    Mp, Np = -M % block_m, -N % block_n
    w = jnp.pad(w, ((0, Mp), (0, Np)))
    s2 = jnp.pad(s2, ((0, Mp), (0, Np)), constant_values=1.0)
    s1 = jnp.pad(jnp.broadcast_to(s1.astype(jnp.float32), (1, N)),
                 ((0, 0), (0, Np)), constant_values=1.0)
    s3 = jnp.pad(jnp.broadcast_to(s3.astype(jnp.float32), (1, N)),
                 ((0, 0), (0, Np)), constant_values=1.0)
    zero = jnp.pad(jnp.broadcast_to(zero.astype(jnp.float32), (1, N)),
                   ((0, 0), (0, Np)))
    Mf, Nf = M + Mp, N + Np
    assert_grid_divisible("flexround_quant", M=(Mf, block_m), N=(Nf, block_n))
    grid = (Mf // block_m, Nf // block_n)
    row_spec = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
    out = pl.pallas_call(
        functools.partial(_kernel, qmin=qmin, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            row_spec,
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            row_spec,
            row_spec,
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mf, Nf), w.dtype),
        interpret=interpret,
    )(w, s1, s2.astype(jnp.float32), s3, zero)
    return out[:M, :N]
