"""Serializable record of an automatic bit allocation.

``AllocationReport`` bundles the probe scores, the solver's choice and the
budget accounting into one JSON document. It is persisted through
``repro.checkpoint`` (``<resume_dir>/allocation.json``) so that

  - a resumed PTQ run re-emits the identical rules without re-probing, and
  - a resume whose rules or allocation digest no longer match fails loudly
    with the allocation named (see ``PTQCheckpointer.load``).

The ``digest`` covers exactly the allocation *decision* (budget, objective
and the chosen bits per site) — probe timings and scores are recorded but
excluded, so re-probing on different hardware cannot invalidate a resume
that still quantizes identically.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Optional, Tuple

from repro.core.quant_config import SiteRule, exact_site_pattern

from repro.allocate.sensitivity import ProbeResult
from repro.allocate.solve import Allocation


@dataclasses.dataclass
class AllocationReport:
    name: str
    budget: Dict[str, object]      # {"kind", "value"}
    objective: str
    solver: str
    # site -> {"bits", "numel", "bytes", "scores": {str(bits): {...}}}
    sites: Dict[str, dict]
    summary: Dict[str, float]      # avg_bits / total_bytes / cost / capacity
    probe: Dict[str, float]        # steps / seconds / steps_per_s / compiles

    @classmethod
    def build(cls, probe: ProbeResult, alloc: Allocation,
              name: Optional[str] = None) -> "AllocationReport":
        sites = {}
        for site, per in sorted(probe.scores.items()):
            chosen = alloc.bits[site]
            sites[site] = {
                "bits": chosen,
                "numel": per[chosen].numel,
                "bytes": per[chosen].cost_bytes,
                "scores": {str(b): {"mse": s.mse, "fisher": s.fisher,
                                    "bytes": s.cost_bytes}
                           for b, s in sorted(per.items())},
            }
        tag = name or (f"auto{alloc.budget.value:g}-{alloc.budget.kind}"
                       f"-{alloc.objective}")
        return cls(
            name=tag,
            budget={"kind": alloc.budget.kind, "value": alloc.budget.value},
            objective=alloc.objective,
            solver=alloc.solver,
            sites=sites,
            summary={"avg_bits": alloc.avg_bits,
                     "total_bytes": alloc.total_bytes,
                     "predicted_score": alloc.predicted_score,
                     "cost": alloc.cost, "capacity": alloc.capacity},
            probe={"steps": probe.steps, "seconds": probe.seconds,
                   "steps_per_s": probe.steps_per_s,
                   "compile_count": probe.compile_count},
        )

    # ------------------------------------------------------------- identity
    def bits(self) -> Dict[str, int]:
        return {site: int(d["bits"]) for site, d in self.sites.items()}

    def digest(self) -> str:
        doc = {"budget": self.budget, "objective": self.objective,
               "bits": self.bits()}
        return hashlib.sha256(
            json.dumps(doc, sort_keys=True).encode()).hexdigest()

    def meta(self) -> dict:
        """Compact identity passed into per-block PTQ checkpoints."""
        return {"name": self.name, "digest": self.digest(),
                "budget": dict(self.budget)}

    def rules(self) -> Tuple[SiteRule, ...]:
        """The allocation as ordered per-site rules — append to the user
        recipe with ``recipe.with_rules(*report.rules())``."""
        return tuple(SiteRule.make(exact_site_pattern(s), w_bits=b)
                     for s, b in sorted(self.bits().items()))

    # ---------------------------------------------------------- persistence
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AllocationReport":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def save(self, directory: str) -> str:
        from repro.checkpoint import save_allocation
        return save_allocation(directory, self.to_dict())

    @classmethod
    def load(cls, directory: str) -> Optional["AllocationReport"]:
        from repro.checkpoint import load_allocation
        d = load_allocation(directory)
        return None if d is None else cls.from_dict(d)

    # --------------------------------------------------------------- logging
    def pretty(self) -> str:
        lines = [f"allocation {self.name!r} (solver={self.solver}, "
                 f"objective={self.objective}, digest "
                 f"{self.digest()[:12]}):"]
        for site, d in sorted(self.sites.items()):
            lines.append(f"  {site}: w{d['bits']} "
                         f"({d['numel']} elems, {d['bytes']} B)")
        s = self.summary
        lines.append(f"  budget[{self.budget['kind']}={self.budget['value']}]"
                     f": avg_bits={s['avg_bits']:.3f} "
                     f"bytes={int(s['total_bytes'])} "
                     f"cost={s['cost']:.0f}/{s['capacity']:.0f}")
        lines.append(f"  probe: {int(self.probe['steps'])} probes in "
                     f"{self.probe['seconds']:.2f}s "
                     f"({self.probe['steps_per_s']:.1f}/s, "
                     f"{int(self.probe['compile_count'])} compiles)")
        return "\n".join(lines)


def validate_budget(report: AllocationReport, slack_sites: int = 0) -> bool:
    """True when the recorded allocation's cost is within its budget
    capacity. Both solvers guarantee cost <= capacity, so the default is
    strict; ``slack_sites`` > 0 allows that many single-bit-step roundings
    (one bit at the largest site for ``avg_bits``; the 4->8 half-numel code
    step for ``weight_bytes``) for callers re-checking a hand-edited
    allocation."""
    kind = report.budget["kind"]
    value = float(report.budget["value"])
    sites = report.sites.values()
    if kind == "avg_bits":
        cost = sum(d["numel"] * d["bits"] for d in sites)
        capacity = value * sum(d["numel"] for d in sites)
        step = max((d["numel"] for d in sites), default=0)
    else:
        cost = sum(d["bytes"] for d in sites)
        capacity = value
        step = max(((d["numel"] + 1) // 2 for d in sites), default=0)
    return cost <= capacity + slack_sites * step
