"""Per-site quantization-sensitivity probes (EPTQ-style, paper §3.1 blocks).

Scores every canonical weight site under each candidate bit-width with two
complementary signals, both measured on the calibration set *before* any
rounding is learned:

  mse     block-output MSE with only that site RTN-quantized at ``bits``
          (teacher vs gated student on the full-precision stream) — the
          direct "what breaks if this site goes to b bits" signal.
  fisher  a diagonal-Fisher / loss-perturbation proxy (AdaRound Eq. (3)
          lineage): for y = xW the Gauss–Newton diagonal of the output MSE
          w.r.t. W is E[x_i^2], so the expected perturbation is
          sum_i E[x_i^2] * sum_j dW_ij^2 / d_out with dW the RTN rounding
          error. Needs one capture pass per block and pure weight-space math
          — no extra block forwards.

Execution model (rides the PR-3 compile-once engine):

  - the fp stream and teacher outputs come from ``reconstruct.probe_teacher``
    (one compiled teacher per ``BlockHandle.apply_key``);
  - the probe step is a single jitted function per (``apply_key``,
    candidate ``bits``): all sites of a block are fake-quantized inside the
    trace and a *traced one-hot gate* selects which one is live, so probing
    S sites issues S calls of one compiled step instead of S traces. Site
    names are canonicalized with the engine's rename machinery, so the L
    structurally identical layers of a transformer share those traces too —
    the probe pass compiles O(distinct apply_keys) steps, not O(sites)
    (asserted via ``engine_stats().probe_compiles`` in tests).

RTN is used as the probe quantizer regardless of the recipe's method: every
learnable method starts from the RTN grid, so RTN error ordering is the
method-agnostic sensitivity signal (and it needs no optimization).

Scores also carry a *cascade weight* (L - block_index): sequential
reconstruction feeds each block the already-quantized stream, so damage at
depth i is paid by every later block. The solver multiplies scores by it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import paths as pth
from repro.core import reconstruct as rec
from repro.core import rtn
from repro.core.context import QuantCtx
from repro.core.quant_config import QuantConfig, QuantRecipe
from repro.obs.telemetry import TELEMETRY, Stopwatch

DEFAULT_BITS = (2, 3, 4, 8)


@dataclasses.dataclass(frozen=True)
class SiteScore:
    """Sensitivity of one site at one candidate bit-width."""
    site: str
    bits: int
    mse: float        # calibration block-output MSE, this site alone quantized
    fisher: float     # diagonal-Fisher / loss-perturbation proxy
    cost_bytes: int   # serving bytes of this site's QTensor at `bits`
    numel: int        # weight elements (cost unit for avg_bits budgets)
    # Cascade weight: block-local damage at depth i corrupts the quantized
    # stream feeding every later block, so sequential reconstruction pays it
    # ~(L - i) times. The solver multiplies scores by this; measured on the
    # smoke LM it is the difference between the allocator beating uniform W4
    # and losing to it.
    cascade: float = 1.0


@dataclasses.dataclass
class ProbeResult:
    """All probe scores plus the pass's cost accounting."""
    scores: Dict[str, Dict[int, SiteScore]]  # site -> bits -> score
    steps: int           # probe forward evaluations executed
    seconds: float
    compile_count: int   # probe-step + teacher traces this pass triggered

    @property
    def steps_per_s(self) -> float:
        return self.steps / max(self.seconds, 1e-9)

    def sites(self) -> Tuple[str, ...]:
        return tuple(sorted(self.scores))


class _ProbeCtx:
    """Gated probe context: each site's effective weight is either the raw
    weight or its RTN fake-quant, selected by a *traced* boolean gate; all
    activations stay fp. One-hot gates isolate a single site per call while
    keeping the compiled HLO identical across a block's sites, so one trace
    serves every site of the block."""

    __slots__ = ("_fp", "_cfgs", "_wstates", "_gates")

    def __init__(self, cfgs: Dict[str, QuantConfig], wstates: Dict[str, Any],
                 gates: Dict[str, jax.Array]):
        self._fp = QuantCtx(mode="fp")
        self._cfgs = cfgs
        self._wstates = wstates
        self._gates = gates

    def _gated(self, name, w):
        cfg = self._cfgs.get(name)
        if cfg is None or name not in self._wstates:
            return w
        w_hat = rtn.apply(w, self._wstates[name], cfg)
        return jnp.where(self._gates[name], w_hat, w).astype(w.dtype)

    def linear(self, name, x, w, b=None, batch_dims=0):
        return self._fp.linear(name, x, self._gated(name, w), b,
                               batch_dims=batch_dims)

    def conv2d(self, name, x, w, b=None, **kwargs):
        return self._fp.conv2d(name, x, self._gated(name, w), b, **kwargs)

    def get_weight(self, name, w, batch_dims=0):
        return self._gated(name, w)

    def __getattr__(self, item):
        return getattr(self._fp, item)


def _probe_key(block: rec.BlockHandle, plans, canon, bits: int,
               recipe: QuantRecipe):
    akey = (block.apply_key if block.apply_key is not None
            else ("~obj", id(block.apply)))
    sites = tuple(sorted(
        (canon[rn], s.kind, s.batch_dims, plans[rn].cache_key())
        for rn, s in block.sites.items()))
    return (akey, sites, bits, recipe)


def _build_probe(block: rec.BlockHandle, cfgs_c: Dict[str, QuantConfig],
                 mapping: Dict[str, str]):
    block_apply = block.apply

    def probe(params, x, y_fp, wstates, gates):
        rec.count_probe_compile()
        ctx = _ProbeCtx(cfgs_c, wstates, gates)
        y = block_apply(params, x, rec._RenameCtx(ctx, mapping))
        return jnp.mean(jnp.square(y.astype(jnp.float32) -
                                   y_fp.astype(jnp.float32)))

    return jax.jit(probe)


def _site_bytes(w: jax.Array, state: Dict[str, jax.Array], bits: int,
                batch_dims: int) -> int:
    """Serving bytes this site would occupy as a QTensor at ``bits``: packed
    codes + the affine grid, mirroring ``qtensor.from_codes`` storage (<=4
    bits nibble-pack along the first non-batch axis when its dim is even)."""
    numel = w.size
    pack_axis = min(batch_dims, w.ndim - 1)
    packed = bits <= 4 and w.shape[pack_axis] % 2 == 0
    code_bytes = numel // 2 if packed else numel
    grid_bytes = 4 * (state["s1"].size + state["zero"].size)
    return int(code_bytes + grid_bytes)


def _fisher_proxy(dw: jax.Array, m2: Optional[jax.Array]) -> float:
    """sum_i E[x_i^2] sum_j dW_ij^2 / d_out with the input-feature axis at
    -2 (linear (d_in, d_out), conv (kh, kw, cin, cout), stacked experts
    (E, d_in, d_out) all store it there). ``m2`` is the captured per-feature
    second moment; None (site never exercised by the capture pass) degrades
    to an unweighted squared error."""
    dw32 = dw.astype(jnp.float32)
    if m2 is None:
        return float(jnp.sum(dw32 * dw32) / dw.shape[-1])
    return float(jnp.sum(m2[:, None] * dw32 * dw32) / dw.shape[-1])


def probe_blocks(blocks: Sequence[rec.BlockHandle], recipe: QuantRecipe,
                 x0: jax.Array, bits: Sequence[int] = DEFAULT_BITS,
                 mesh=None) -> ProbeResult:
    """Score every site of every block at each candidate bit-width.

    Runs on the full-precision stream (probing happens before any site is
    finalized): block b's probe input is the teacher output of block b-1.
    Per-site rules in ``recipe`` shape the probe configs (granularity,
    symmetry, observer) — only ``bits`` is swept.

    ``mesh``: optional data-parallel mesh — the fp stream is sharded over
    the data axes on the leading sample axis exactly like the recon entry
    points, and the probe pass stays compile-flat (one probe step per
    (apply_key, bits) regardless of the mesh; the block-output MSE is a mean
    over the global batch, so it psums automatically under jit).
    """
    stats0 = dataclasses.replace(rec.engine_stats())
    sw = Stopwatch()
    steps = 0
    scores: Dict[str, Dict[int, SiteScore]] = {}
    probe_cache: Dict[Any, Any] = {}

    if mesh is not None:
        from repro.launch.sharding import stream_sharding
        x0 = jax.device_put(x0, stream_sharding(mesh, x0.shape[0]))

    with rec.engine_scope():
        x = x0
        for bi, block in enumerate(blocks):
            cascade = float(len(blocks) - bi)
            with TELEMETRY.span("alloc.teacher", block=block.name) as tsp:
                y_fp = rec.probe_teacher(block, recipe, mesh)(block.params, x)
                tsp.block_on(y_fp)
            plans = rec.site_plans(block, recipe)
            canon = rec._canon_names(block)

            # one capture pass per block: per-site input second moments for
            # the fisher proxy
            cap = QuantCtx(mode="capture", recipe=recipe)
            block.apply(block.params, x, cap)
            m2 = {}
            for rn in block.sites:
                xs = cap.records.get(rn)
                if xs:
                    x32 = xs[0].astype(jnp.float32)
                    m2[rn] = jnp.mean(x32 * x32,
                                      axis=tuple(range(x32.ndim - 1)))

            for b in bits:
                cfgs_c = {canon[rn]: dataclasses.replace(plans[rn].weight,
                                                         bits=b)
                          for rn in block.sites}
                pkey = _probe_key(block, plans, canon, b, recipe)
                probe_fn = probe_cache.get(pkey)
                if probe_fn is None:
                    probe_fn = _build_probe(block, cfgs_c, canon)
                    probe_cache[pkey] = probe_fn

                wstates, deltas = {}, {}
                for rn, site in block.sites.items():
                    w = pth.get_path(block.params, site.path)
                    st = rtn.init(w, cfgs_c[canon[rn]])
                    wstates[canon[rn]] = st
                    deltas[rn] = (w, st,
                                  rtn.apply(w, st, cfgs_c[canon[rn]]) - w)

                for rn, site in block.sites.items():
                    gates = {c: jnp.asarray(c == canon[rn])
                             for c in canon.values()}
                    # float() syncs, so the probe span needs no block_on
                    with TELEMETRY.span("alloc.probe", block=block.name,
                                        site=rn, bits=b):
                        mse = float(probe_fn(block.params, x, y_fp, wstates,
                                             gates))
                    steps += 1
                    w, st, dw = deltas[rn]
                    scores.setdefault(rn, {})[b] = SiteScore(
                        site=rn, bits=b, mse=mse,
                        fisher=_fisher_proxy(dw, m2.get(rn)),
                        cost_bytes=_site_bytes(w, st, b, site.batch_dims),
                        numel=int(w.size), cascade=cascade)
            x = y_fp  # advance the fp stream

    st1 = rec.engine_stats()
    compiles = ((st1.probe_compiles - stats0.probe_compiles) +
                (st1.teacher_compiles - stats0.teacher_compiles))
    return ProbeResult(scores=scores, steps=steps,
                       seconds=sw.elapsed_s(), compile_count=compiles)
