"""Budgeted bit allocation over probed site sensitivities.

Multiple-choice knapsack: each site picks exactly one bit-width from the
probed candidates; minimize the summed sensitivity score subject to a
budget. Two solvers, selected automatically:

  greedy  marginal-gain on each site's efficient frontier: dominated levels
          (no score gain for extra cost) are dropped, the rest reduced to
          the lower convex hull so per-site upgrade ratios decrease, then
          all upgrade segments are applied globally in decreasing
          score-drop-per-cost order while they fit. Near-optimal, O(n log n).

  dp      exact dynamic program over integer costs (costs divided by their
          gcd). Used when the integer cost grid is small enough
          (``cells = n_sites * (capacity_int + 1) <= DP_CELL_CAP``) — the
          "exact small-N" regime; bigger problems fall back to greedy.

Budgets:

  avg_bits      numel-weighted average bits: capacity = value * total_numel,
                cost(site, b) = numel * b.
  weight_bytes  serving bytes (packed codes + affine grid, the same
                accounting as ``qtensor.tree_weight_bytes``): capacity =
                value, cost(site, b) = probed ``cost_bytes``. Note <=4-bit
                QTensors all store nibble-packed codes, so 2/3/4-bit levels
                cost the same bytes — the frontier collapses them to the
                best-scoring one.

The emitted ``SiteRule``s use exact (glob-escaped) site-name patterns and
are meant to be appended to the user recipe via ``recipe.with_rules`` —
later rules win, so the allocation overrides defaults and earlier rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

from repro.core.quant_config import SiteRule, exact_site_pattern

from repro.allocate.sensitivity import ProbeResult, SiteScore

BUDGET_KINDS = ("avg_bits", "weight_bytes")
OBJECTIVES = ("mse", "fisher", "combined")
DP_CELL_CAP = 4_000_000  # n_sites * (capacity_int + 1) ceiling for exact DP


@dataclasses.dataclass(frozen=True)
class Budget:
    """A bit budget: ``kind`` selects the cost model (see module doc)."""
    kind: str
    value: float

    def __post_init__(self):
        if self.kind not in BUDGET_KINDS:
            raise ValueError(f"budget kind {self.kind!r} not in "
                             f"{BUDGET_KINDS}")
        if not self.value > 0:
            raise ValueError(f"budget value must be > 0, got {self.value}")


@dataclasses.dataclass
class Allocation:
    """Solver output: chosen bits per site + budget accounting."""
    bits: Dict[str, int]
    budget: Budget
    solver: str            # "greedy" | "dp"
    objective: str
    predicted_score: float  # summed objective score of the chosen levels
    cost: float             # achieved cost in budget units
    capacity: float         # budget capacity in the same units
    avg_bits: float         # numel-weighted average of the chosen bits
    total_bytes: int        # summed per-site QTensor bytes

    def rules(self) -> Tuple[SiteRule, ...]:
        """Ordered per-site rules (exact-name patterns, deterministic
        order) compatible with ``recipe.resolve`` / ``recipe.with_rules``."""
        return tuple(SiteRule.make(exact_site_pattern(s), w_bits=b)
                     for s, b in sorted(self.bits.items()))


@dataclasses.dataclass(frozen=True)
class _Level:
    bits: int
    cost: int      # integer cost in budget units
    score: float
    bytes: int
    numel: int


def _objective_scores(probes: Dict[str, Dict[int, SiteScore]],
                      objective: str) -> Dict[str, Dict[int, float]]:
    """Collapse (mse, fisher) to one scalar per (site, bits). ``combined``
    sums the two metrics after normalizing each by its mean over all
    entries, so neither scale dominates."""
    if objective not in OBJECTIVES:
        raise ValueError(f"objective {objective!r} not in {OBJECTIVES}")
    entries = [s for per in probes.values() for s in per.values()]
    mse_norm = sum(s.mse for s in entries) / max(len(entries), 1) or 1.0
    fis_norm = sum(s.fisher for s in entries) / max(len(entries), 1) or 1.0

    def one(s: SiteScore) -> float:
        # cascade-weight all objectives: damage at depth i is paid by every
        # later block of the sequential reconstruction (see SiteScore)
        if objective == "mse":
            return s.cascade * s.mse
        if objective == "fisher":
            return s.cascade * s.fisher
        return s.cascade * (s.mse / mse_norm + s.fisher / fis_norm)

    return {site: {b: one(s) for b, s in per.items()}
            for site, per in probes.items()}


def _site_levels(probes: Dict[str, Dict[int, SiteScore]],
                 obj: Dict[str, Dict[int, float]],
                 budget: Budget) -> Dict[str, List[_Level]]:
    out = {}
    for site, per in probes.items():
        levels = []
        for b, s in sorted(per.items()):
            cost = s.numel * b if budget.kind == "avg_bits" else s.cost_bytes
            levels.append(_Level(bits=b, cost=int(cost), score=obj[site][b],
                                 bytes=s.cost_bytes, numel=s.numel))
        out[site] = sorted(levels, key=lambda l: (l.cost, l.score, l.bits))
    return out


def _frontier(levels: List[_Level]) -> List[_Level]:
    """Efficient frontier: drop dominated levels (no strict score drop for
    extra cost), then reduce to the lower convex hull so consecutive
    upgrade ratios (score drop per unit cost) are non-increasing."""
    front: List[_Level] = []
    for l in levels:  # cost-ascending
        if front and l.score >= front[-1].score:
            continue  # dominated: costs more (or same), scores no better
        if front and l.cost == front[-1].cost:
            front[-1] = l  # same cost, strictly better score
            continue
        front.append(l)
    hull: List[_Level] = []
    for p in front:
        while len(hull) >= 2:
            a, b = hull[-2], hull[-1]
            # pop b if jumping a->p is at least as efficient as a->b
            if (a.score - b.score) * (p.cost - a.cost) <= \
                    (a.score - p.score) * (b.cost - a.cost):
                hull.pop()
            else:
                break
        hull.append(p)
    return hull


def _greedy(fronts: Dict[str, List[_Level]], capacity: int
            ) -> Tuple[Dict[str, int], float, int]:
    chosen = {site: 0 for site in fronts}  # index into the site's frontier
    cost = sum(f[0].cost for f in fronts.values())
    score = sum(f[0].score for f in fronts.values())
    segments = []
    for site, f in fronts.items():
        for i in range(len(f) - 1):
            dcost = f[i + 1].cost - f[i].cost
            gain = f[i].score - f[i + 1].score
            segments.append((gain / max(dcost, 1e-12), gain, site, i))
    # decreasing efficiency; deterministic tie-break
    segments.sort(key=lambda s: (-s[0], -s[1], s[2], s[3]))
    for ratio, gain, site, i in segments:
        f = fronts[site]
        if chosen[site] != i:
            continue  # an earlier (more efficient) upgrade was skipped
        dcost = f[i + 1].cost - f[i].cost
        if cost + dcost > capacity:
            continue
        chosen[site] = i + 1
        cost += dcost
        score -= gain
    return ({site: fronts[site][i].bits for site, i in chosen.items()},
            score, cost)


def _dp(fronts: Dict[str, List[_Level]], capacity: int
        ) -> Tuple[Dict[str, int], float, int]:
    """Exact multiple-choice knapsack over an integerized cost grid."""
    sites = sorted(fronts)
    unit = 0
    for f in fronts.values():
        for l in f:
            unit = math.gcd(unit, l.cost)
    unit = max(unit, 1)
    cap = capacity // unit
    dp = np.zeros(cap + 1, np.float64)  # zero sites placed: score 0 any cost
    choice = np.zeros((len(sites), cap + 1), np.int16)
    for k, site in enumerate(sites):
        new = np.full(cap + 1, np.inf)
        pick = np.zeros(cap + 1, np.int16)
        for li, l in enumerate(fronts[site]):
            c = l.cost // unit
            if c > cap:
                continue
            cand = np.full(cap + 1, np.inf)
            cand[c:] = dp[:cap + 1 - c] + l.score
            better = cand < new
            new[better] = cand[better]
            pick[better] = li
        dp, choice[k] = new, pick
    if not np.isfinite(dp).any():
        raise ValueError("bit budget infeasible: even the cheapest levels "
                         "exceed the capacity")
    c = int(np.argmin(dp))
    score = float(dp[c])
    bits, cost = {}, 0
    for k in range(len(sites) - 1, -1, -1):
        l = fronts[sites[k]][int(choice[k, c])]
        bits[sites[k]] = l.bits
        cost += l.cost
        c -= l.cost // unit
    return bits, score, cost


def solve_allocation(probe: ProbeResult, budget: Budget,
                     objective: str = "combined",
                     solver: str = "auto") -> Allocation:
    """Pick one bit-width per probed site under ``budget``.

    ``solver``: "auto" runs the exact DP when the integer cost grid is small
    enough and greedy otherwise; "greedy"/"dp" force one (dp raises if its
    grid would exceed ``DP_CELL_CAP``).
    """
    if solver not in ("auto", "greedy", "dp"):
        raise ValueError(f"solver {solver!r} not in ('auto', 'greedy', 'dp')")
    probes = probe.scores
    if not probes:
        raise ValueError("no probed sites to allocate over")
    obj = _objective_scores(probes, objective)
    levels = _site_levels(probes, obj, budget)
    if budget.kind == "avg_bits":
        total_numel = sum(per[min(per)].numel for per in probes.values())
        capacity = int(budget.value * total_numel)
    else:
        capacity = int(budget.value)
    fronts = {site: _frontier(ls) for site, ls in levels.items()}
    floor = sum(f[0].cost for f in fronts.values())
    if floor > capacity:
        raise ValueError(
            f"bit budget infeasible: cheapest allocation costs {floor} "
            f"{budget.kind} units but the capacity is {capacity}")

    unit = 0
    for f in fronts.values():
        for l in f:
            unit = math.gcd(unit, l.cost)
    cells = len(fronts) * (capacity // max(unit, 1) + 1)
    use_dp = solver == "dp" or (solver == "auto" and cells <= DP_CELL_CAP)
    if solver == "dp" and cells > DP_CELL_CAP:
        raise ValueError(f"dp solver grid too large ({cells} cells > "
                         f"{DP_CELL_CAP}); use solver='greedy'")
    bits, score, cost = (_dp if use_dp else _greedy)(fronts, capacity)

    by_site = {site: {l.bits: l for l in ls} for site, ls in levels.items()}
    chosen = {site: by_site[site][b] for site, b in bits.items()}
    total_numel = sum(l.numel for l in chosen.values())
    return Allocation(
        bits=bits, budget=budget, solver="dp" if use_dp else "greedy",
        objective=objective, predicted_score=score, cost=float(cost),
        capacity=float(capacity),
        avg_bits=sum(l.numel * b for (b, l) in
                     ((bits[s], chosen[s]) for s in chosen)) / total_numel,
        total_bytes=sum(l.bytes for l in chosen.values()))
