"""Sensitivity-guided automatic mixed-precision allocation.

Probe -> solve -> rules: score every canonical weight site under candidate
bit-widths on the calibration set (``sensitivity``), pick one bit-width per
site under an ``avg_bits`` or ``weight_bytes`` budget (``solve``), and emit
ordered ``SiteRule``s that lay on top of any ``QuantRecipe`` via
``recipe.with_rules`` (``report``). The probe pass rides the compile-once
reconstruction engine, so it compiles O(distinct ``apply_key``s) steps —
not O(sites).

One-call entry:

    report = auto_allocate(blocks, recipe, x0,
                           Budget("avg_bits", 4.5))
    recipe = recipe.with_rules(*report.rules())
    quantize_blocks(blocks, recipe, x0, allocation=report.meta())
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.allocate.report import AllocationReport, validate_budget  # noqa: F401
from repro.allocate.sensitivity import (DEFAULT_BITS, ProbeResult,  # noqa: F401
                                        SiteScore, probe_blocks)
from repro.allocate.solve import (Allocation, Budget,  # noqa: F401
                                  solve_allocation)


def auto_allocate(blocks, recipe, x0, budget: Budget, *,
                  bits: Sequence[int] = DEFAULT_BITS,
                  objective: str = "combined", solver: str = "auto",
                  name: Optional[str] = None, mesh=None) -> AllocationReport:
    """Probe every site, solve the budget, return the report (rules +
    accounting). The caller applies ``report.rules()`` to its recipe and
    passes ``report.meta()`` to ``quantize_blocks`` for resume validation.
    ``mesh`` shards the probe pass's calibration stream over the data axes
    (see ``probe_blocks``)."""
    probe = probe_blocks(blocks, recipe, x0, bits=bits, mesh=mesh)
    alloc = solve_allocation(probe, budget, objective=objective,
                             solver=solver)
    return AllocationReport.build(probe, alloc, name=name)
