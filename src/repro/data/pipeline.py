"""Data pipeline: synthetic token streams + calibration sets.

The paper's PTQ needs only a small calibration sample (128-1024 sequences);
pretraining the small example models needs a token stream. Both are built on
a deterministic counter-based RNG so any host can materialize exactly its
shard for any step — the property that makes restart/elastic-scale trivial:

    batch(step, host, n_hosts) is a pure function.

Straggler mitigation: ``assemble_global_batch`` takes per-host fetch results
with a deadline; missing shards are dropped and the loss weight rescaled
(simulated single-host here; the policy + math are the real thing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _key(seed: int, *fold: int) -> jax.Array:
    k = jax.random.key(seed)
    for f in fold:
        k = jax.random.fold_in(k, f)
    return k


class SyntheticTokens:
    """Markov-ish synthetic corpus: learnable but non-trivial structure.

    Tokens follow t_{i+1} = (a * t_i + b + noise) mod V with per-sequence
    (a, b) drawn from a small set — a model must use context to predict,
    so cross-entropy meaningfully separates fp vs quantized models.
    """

    def __init__(self, vocab: int, seq_len: int, seed: int = 0,
                 n_modes: int = 8):
        self.vocab = vocab
        self.seq_len = seq_len
        self.seed = seed
        self.n_modes = n_modes

    def batch(self, step: int, batch_size: int, host: int = 0,
              n_hosts: int = 1) -> Dict[str, jax.Array]:
        """Deterministic global batch shard for (step, host)."""
        if n_hosts < 1 or not 0 <= host < n_hosts:
            raise ValueError(
                f"host index {host} out of range for n_hosts={n_hosts}")
        if batch_size % n_hosts:
            raise ValueError(
                f"global batch_size={batch_size} does not divide over "
                f"n_hosts={n_hosts} (per-host shards must be equal-sized; "
                f"got remainder {batch_size % n_hosts})")
        local = batch_size // n_hosts
        k = _key(self.seed, step, host)
        ka, kb, kt, kn = jax.random.split(k, 4)
        a = jax.random.randint(ka, (local, 1), 1, self.n_modes + 1)
        b = jax.random.randint(kb, (local, 1), 0, self.vocab)
        t0 = jax.random.randint(kt, (local, 1), 0, self.vocab)
        noise = jax.random.randint(kn, (local, self.seq_len + 1), 0, 3)
        idx = jnp.arange(self.seq_len + 1)[None, :]
        # closed form of the affine recurrence keeps generation vectorized
        toks = jnp.mod(t0 * jnp.power(a, idx)
                       + b * idx + jnp.cumsum(noise, axis=1), self.vocab)
        toks = toks.astype(jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class CalibrationSet:
    """The paper's calibration sample: N random sequences from the 'training
    distribution' (synthetic here), fixed once per PTQ run."""

    tokens: jax.Array  # (N, S)

    @staticmethod
    def build(source: SyntheticTokens, n_samples: int, seed: int = 1234
              ) -> "CalibrationSet":
        per = max(1, n_samples // 4)
        batches = [source.batch(10_000 + i, per)["tokens"]
                   for i in range((n_samples + per - 1) // per)]
        toks = jnp.concatenate(batches, axis=0)[:n_samples]
        return CalibrationSet(tokens=toks)

    @staticmethod
    def build_sharded(source: SyntheticTokens, n_samples: int, n_hosts: int,
                      policy: Optional["StragglerPolicy"] = None,
                      drop_hosts: Sequence[int] = (),
                      ) -> Tuple["CalibrationSet", jax.Array]:
        """Per-host calibration assembly (the multi-host PTQ entry).

        Each host materializes exactly its shard — ``batch(step, host,
        n_hosts)`` is a pure function, so no host ever sees another host's
        data — and the shards combine through the straggler policy. Returns
        ``(calibration_set, weight)`` where ``weight`` is the (N,) per-sample
        loss mask from ``assemble_global_batch``: samples from dropped hosts
        are zero-filled and carry weight 0, and the reconstruction objective
        consumes the mask as a weighted global-batch mean (gradient magnitude
        stays unbiased). ``drop_hosts`` simulates deadline misses
        (single-process smoke/tests; real deployments pass None for hosts
        that missed the fetch deadline).
        """
        shards: List[Optional[Dict[str, np.ndarray]]] = []
        for h in range(n_hosts):
            if h in drop_hosts:
                shards.append(None)
                continue
            shard = source.batch(10_000, n_samples, host=h, n_hosts=n_hosts)
            shards.append({k: np.asarray(v) for k, v in shard.items()})
        batch, weight = assemble_global_batch(
            shards, policy or StragglerPolicy())
        return CalibrationSet(tokens=batch["tokens"]), weight

    def __len__(self):
        return int(self.tokens.shape[0])


# -------------------------------------------------------------- stragglers
@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """Deadline-based shard dropping for global batch assembly."""

    deadline_ms: float = 100.0
    min_fraction: float = 0.75  # below this, wait anyway (quality floor)


def assemble_global_batch(shards: Sequence[Optional[Dict[str, np.ndarray]]],
                          policy: StragglerPolicy
                          ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Combine per-host shards; None = host missed the deadline.

    Returns (batch, weight) where missing shards are zero-filled and
    ``weight`` (B,) masks them out; callers rescale the loss by
    B / weight.sum() so gradient magnitude is unbiased.
    """
    present = [s for s in shards if s is not None]
    if not present:
        raise RuntimeError("all shards missed the deadline")
    frac = len(present) / len(shards)
    if frac < policy.min_fraction:
        raise TimeoutError(
            f"only {frac:.0%} of shards arrived (< {policy.min_fraction:.0%})")
    proto_host = next(h for h, s in enumerate(shards) if s is not None)
    proto = shards[proto_host]
    # every present shard must agree with the prototype, keys and shapes
    # both — a silent mismatch would zero-fill or mis-concatenate a live
    # host's data
    for h, s in enumerate(shards):
        if s is None:
            continue
        if set(s) != set(proto):
            raise ValueError(
                f"host {h} shard keys {sorted(s)} do not match host "
                f"{proto_host}'s {sorted(proto)}")
        for k in proto:
            if np.shape(s[k]) != np.shape(proto[k]):
                raise ValueError(
                    f"host {h} shard {k!r} has shape {np.shape(s[k])} but "
                    f"host {proto_host} has {np.shape(proto[k])}; per-host "
                    "shards must be equal-sized")
    out: Dict[str, List[np.ndarray]] = {k: [] for k in proto}
    weights = []
    for s in shards:
        use = s if s is not None else {k: np.zeros_like(v)
                                       for k, v in proto.items()}
        for k in proto:
            out[k].append(use[k])
        weights.append(np.full((proto["tokens"].shape[0],),
                               0.0 if s is None else 1.0, np.float32))
    batch = {k: jnp.concatenate([jnp.asarray(v) for v in vs], axis=0)
             for k, vs in out.items()}
    return batch, jnp.concatenate([jnp.asarray(w) for w in weights])
