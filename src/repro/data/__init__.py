from repro.data.pipeline import (  # noqa: F401
    CalibrationSet,
    SyntheticTokens,
    StragglerPolicy,
    assemble_global_batch,
)
