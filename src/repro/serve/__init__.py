"""Quantized continuous-batching serving engine (ROADMAP Open item #1).

Layout:
  kv.py        int8 KV cache: quantizer, dequant-free decode attention,
               HBM accounting, KVQuantUnsupported
  engine.py    bucketed AOT prefill + slot-based decode over the deploy path
  scheduler.py host-side admission queue + async detokenize thread
  smoke.py     machine-readable serve-capability probe shared by
               launch/quantize and benchmarks

``repro.serve.kv`` must stay importable from ``repro.models`` (the model
families quantize-on-append through it), so this package imports models-side
code lazily: ``from repro.serve import ServeEngine`` works, but merely
importing ``repro.serve`` (as the models do for ``kv``) pulls in nothing
beyond jax.
"""
from repro.serve.kv import (  # noqa: F401
    KV_SCALE_MIN,
    KVQuantUnsupported,
    hbm_per_slot_mib,
    int8_decode_attention,
    kv_dequantize,
    kv_quantize,
)

_LAZY = {
    "ServeEngine": "repro.serve.engine",
    "EngineConfig": "repro.serve.engine",
    "Scheduler": "repro.serve.scheduler",
    "Request": "repro.serve.scheduler",
    "serve_capability": "repro.serve.smoke",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
