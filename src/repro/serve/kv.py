"""First-class int8 KV cache for the serving engine.

Per-(token, head) absmax quantization of K/V entries — promoted out of
``models/transformer.py`` so every attention family and the serving engine
share one quantizer, one layout, and one accounting of HBM-per-slot:

  codes  int8   (..., S, H, D)    the K/V entries on the [-127, 127] grid
  scale  f32    (..., S, H, 1)    absmax/127, floored at KV_EPS/127

The floor is the value contract quantcheck (QL303) proves against: every
stored scale is >= :data:`KV_SCALE_MIN` (~7.9e-9), five orders of magnitude
above the float32 subnormal boundary, so no dequant multiply or
quantize-on-append divide can flush to zero. The serving trace entries
(``analysis/trace.py: serve_decode_entry``) declare these ranges.

:func:`int8_decode_attention` is the dequant-free score path: the cache is
never rematerialized in the KV dtype. Because the scale is constant over the
head dim, ``q . (codes * scale) == (q . codes) * scale`` exactly, so scores
contract q against the int8 codes and fold the scale in afterwards; on the
value side the per-token scale folds into the softmax probabilities before
the probs-x-codes contraction. HBM traffic per decode step is therefore the
int8 codes plus one f32 scalar per (token, head) — 1.125 B/elem at D=32
versus 2 (bf16) or 4 (f32) — which is what turns W4 weights into more
concurrent users per chip. (No int8 attention Pallas kernel exists yet —
the kernel table covers matmuls only — so this path expresses the
order-of-operations in XLA; a future kernel slots in behind the same
signature.)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
KV_EPS = 1e-6
KV_QMAX = 127.0
# smallest scale the quantizer can store: the contract QL303 proves against
KV_SCALE_MIN = KV_EPS / KV_QMAX


class KVQuantUnsupported(ValueError):
    """A model family was asked for an int8 KV cache it cannot have.

    Raised (instead of a bare ``TypeError``) by ``init_cache(kv_quant=True)``
    on families with no attention KV cache (ssm, rglru recurrent state) or a
    latent cache that is already compressed (MLA). ``reason`` is the
    machine-readable tag the serving engine and benchmarks surface.
    """

    def __init__(self, reason: str, detail: str):
        self.reason = reason
        super().__init__(f"{reason}: {detail}")


def kv_quantize(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(token, head) absmax int8 quantization of K/V entries."""
    t32 = t.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(t32), axis=-1, keepdims=True),
                        KV_EPS) / KV_QMAX
    codes = jnp.clip(jnp.round(t32 / scale), -KV_QMAX, KV_QMAX)
    return codes.astype(jnp.int8), scale


def kv_dequantize(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def quantized_cache(cache) -> bool:
    """Does this cache dict hold int8 codes + scales (vs raw K/V)?"""
    return isinstance(cache, dict) and "k_scale" in cache


def _pos_mask(pos, B: int, Smax: int, window: int) -> jax.Array:
    """(B, Smax) validity mask; ``pos`` is scalar or per-row (B,)."""
    k_pos = jnp.arange(Smax)
    pos = jnp.asarray(pos)
    posb = jnp.broadcast_to(pos.reshape(-1, 1) if pos.ndim else pos, (B, 1))
    valid = k_pos[None, :] <= posb
    if window > 0:
        valid &= k_pos[None, :] > posb - window
    return valid


def int8_decode_attention(q: jax.Array, k_codes: jax.Array,
                          k_scale: jax.Array, v_codes: jax.Array,
                          v_scale: jax.Array, pos, *,
                          window: int = 0) -> jax.Array:
    """Single-token decode attention directly over the int8 cache.

    q (B,1,Hq,D); codes (B,Smax,Hkv,D) int8; scales (B,Smax,Hkv,1) f32.
    ``pos`` is the current token's absolute position — a scalar for a
    uniform batch, or (B,) for the slot-based engine where every slot sits
    at its own depth. The per-(token, head) scales fold in *after* the
    contractions (keys: into the scores; values: into the probabilities),
    so the cache is never dequantized into a (B,Smax,Hkv,D) float tensor.
    """
    B, _, Hq, D = q.shape
    Smax, Hkv = k_codes.shape[1], k_codes.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_codes.astype(jnp.float32))
    # scale (B,Smax,Hkv,1) -> (B,Hkv,1,1,Smax): constant over D, so folding
    # it here is exact (not an approximation of dequant-then-dot)
    k_s = k_scale[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    s = s * k_s * (D ** -0.5)
    valid = _pos_mask(pos, B, Smax, window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * v_scale[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pv, v_codes.astype(jnp.float32))
    return out.reshape(B, 1, Hq, v_codes.shape[-1]).astype(q.dtype)


def cache_bytes(cache) -> int:
    """Total bytes held by a cache pytree (codes + scales + fp arrays)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


def hbm_per_slot_bytes(cache, slots: int) -> int:
    """Bytes of KV state one decode slot pins in HBM, from the live cache
    pytree (codes + scales, or raw K/V). The single accessor the serve
    bench row and the memcheck weight-traffic check (QL403) both read —
    any accounting drift between them is a bug, not a rounding choice."""
    return cache_bytes(cache) // slots


def hbm_per_slot_mib(cache, slots: int) -> float:
    """MiB of KV state one decode slot pins in HBM."""
    return hbm_per_slot_bytes(cache, slots) / 2**20


def unsupported(family: str, detail: str) -> KVQuantUnsupported:
    """Named error for families with no quantizable KV cache."""
    return KVQuantUnsupported(f"kv_quant_unsupported:{family}", detail)


def check_kv_quant_supported(cfg, kv_quant: bool,
                             family: Optional[str] = None) -> None:
    """Shared guard for ``init_cache(kv_quant=...)`` across model families."""
    if not kv_quant:
        return
    fam = family or getattr(cfg, "family", "?")
    if fam in ("ssm", "hybrid"):
        raise unsupported(
            fam, f"{cfg.name}: the {fam} family keeps recurrent state "
            "(conv tail / SSM state / LRU hidden), not an attention KV "
            "cache — there is nothing to int8-quantize per token; serve "
            "it with kv_quant=False")
    if getattr(cfg, "use_mla", False):
        raise unsupported(
            "mla", f"{cfg.name}: MLA caches the compressed latent "
            "(kv_lora_rank per token), which is already the memory "
            "optimization — int8 per-head scales do not apply to the "
            "latent layout; serve it with kv_quant=False")
