"""Host-side scheduler: admission queue + async detokenize thread.

The device loop (``ServeEngine.admit`` / ``.step``) must never wait on the
host, so everything host-flavored lives here:

* **Admission queue** — requests land in a FIFO backlog and are admitted
  whenever slots free up, up to ``prefill_group`` per prefill call. The
  admission is *straggler-tolerant*: a half-empty group ships immediately
  as dummy-padded rows instead of waiting for the backlog to fill the
  group (the compiled prefill has fixed shapes either way), so one slow
  producer cannot stall every other user's first token.

* **Async detokenize thread** — emitted token ids go into a
  ``queue.Queue`` drained by a daemon thread that runs the (potentially
  slow, pure-Python) ``detokenize`` callback; the decode loop only ever
  pays a lock-free put. Ordering per request id is preserved (single
  consumer thread).

``run()`` drives the whole lifecycle for an offline batch; ``submit`` +
``pump`` expose the incremental interface for a live loop.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (n,) int32 prompt
    max_new: int = 16
    detok: List[int] = field(default_factory=list)


_STOP = object()


class Scheduler:
    def __init__(self, engine,
                 detokenize: Optional[Callable[[int, int], None]] = None):
        self.engine = engine
        self.backlog: "queue.Queue[Request]" = queue.Queue()
        self.outputs: Dict[int, List[int]] = {}
        self._detok_fn = detokenize
        self._detok_q: "queue.Queue" = queue.Queue()
        self._detok_thread = threading.Thread(target=self._detok_loop,
                                              daemon=True)
        self._detok_thread.start()
        self._pending = 0  # submitted but not yet fully emitted

    # ---------------------------------------------------------- detok side
    def _detok_loop(self):
        while True:
            item = self._detok_q.get()
            try:
                if item is _STOP:
                    return
                rid, tok = item
                self.outputs.setdefault(rid, []).append(tok)
                if self._detok_fn is not None:
                    self._detok_fn(rid, tok)
            finally:
                self._detok_q.task_done()

    def _emit(self, pairs):
        for rid, tok in pairs:
            self._detok_q.put((rid, tok))

    # --------------------------------------------------------- device side
    def submit(self, req: Request):
        self.backlog.put(req)
        self._pending += 1

    def _admit_some(self):
        """Fill free slots from the backlog — at most one prefill call, at
        most ``prefill_group`` requests, shipped even if the group is
        short (straggler tolerance)."""
        eng = self.engine
        room = min(len(eng.free_slots()), eng.cfg.prefill_group)
        batch = []
        while room > 0 and not self.backlog.empty():
            batch.append(self.backlog.get_nowait())
            room -= 1
        if batch:
            self._emit(eng.admit([(r.rid, r.tokens, r.max_new)
                                  for r in batch]))

    def pump(self) -> bool:
        """One scheduling round: admit, then one decode step across slots.
        Returns False when there is nothing left to do."""
        eng = self.engine
        self._admit_some()
        if eng.active:
            self._emit(eng.step())
        for _rid, _toks in eng.drain_finished():
            self._pending -= 1
        return eng.active > 0 or not self.backlog.empty()

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Offline batch: submit everything, pump to completion, join the
        detokenize thread's queue, return per-request token lists."""
        for r in requests:
            self.submit(r)
        while self._pending > 0:
            self.pump()
        self._detok_q.join()  # all handed tokens consumed by the thread
        return self.outputs

    def close(self):
        self._detok_q.put(_STOP)
        self._detok_thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
