"""Host-side scheduler: admission queue + async detokenize thread.

The device loop (``ServeEngine.admit`` / ``.step``) must never wait on the
host, so everything host-flavored lives here:

* **Admission queue** — requests land in a FIFO backlog and are admitted
  whenever slots free up, up to ``prefill_group`` per prefill call. The
  admission is *straggler-tolerant*: a half-empty group ships immediately
  as dummy-padded rows instead of waiting for the backlog to fill the
  group (the compiled prefill has fixed shapes either way), so one slow
  producer cannot stall every other user's first token.

* **Async detokenize thread** — emitted token ids go into a
  ``queue.Queue`` drained by a daemon thread that runs the (potentially
  slow, pure-Python) ``detokenize`` callback; the decode loop only ever
  pays a lock-free put. Ordering per request id is preserved (single
  consumer thread). A raising callback does **not** kill the drain loop:
  the first exception is recorded on the scheduler, counted as
  ``detok_errors`` in telemetry, and re-raised from ``pump()``/``run()``/
  ``close()`` — the loop keeps draining so ``queue.join()`` never hangs.

* **Request lifecycle metrics** — ``submit`` stamps the enqueue time, and
  the admitting prefill closes the queue-wait (submit → prefill start)
  and TTFT (submit → first token, which the prefill itself emits) windows
  on the engine's :class:`repro.obs.ServeMetrics`; backlog depth and slot
  occupancy are mirrored as gauges. Drain via ``Scheduler.stats()``.

``run()`` drives the whole lifecycle for an offline batch; ``submit`` +
``pump`` expose the incremental interface for a live loop.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs.serve_metrics import ServeMetrics
from repro.obs.telemetry import TELEMETRY, now


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (n,) int32 prompt
    max_new: int = 16
    detok: List[int] = field(default_factory=list)


_STOP = object()


class Scheduler:
    def __init__(self, engine,
                 detokenize: Optional[Callable[[int, int], None]] = None):
        self.engine = engine
        self.metrics: ServeMetrics = (getattr(engine, "metrics", None)
                                      or ServeMetrics())
        self.backlog: "queue.Queue[Request]" = queue.Queue()
        self.outputs: Dict[int, List[int]] = {}
        self._detok_fn = detokenize
        self._detok_q: "queue.Queue" = queue.Queue()
        self._detok_exc: Optional[BaseException] = None
        self._detok_thread = threading.Thread(target=self._detok_loop,
                                              daemon=True)
        self._detok_thread.start()
        self._pending = 0  # submitted but not yet fully emitted

    # ---------------------------------------------------------- detok side
    def _detok_loop(self):
        while True:
            item = self._detok_q.get()
            try:
                if item is _STOP:
                    return
                rid, tok = item
                self.outputs.setdefault(rid, []).append(tok)
                if self._detok_fn is not None:
                    try:
                        self._detok_fn(rid, tok)
                    except BaseException as e:  # noqa: BLE001 - user code
                        # record + count, keep draining: a poisoned
                        # callback must not strand queue.join() forever
                        if self._detok_exc is None:
                            self._detok_exc = e
                        self.metrics.count_detok_error()
            finally:
                self._detok_q.task_done()

    def _raise_detok(self):
        """Surface the first detokenize-callback exception on the caller's
        thread (cleared once raised — close() after a raising run() must
        not raise the same error twice)."""
        if self._detok_exc is not None:
            exc, self._detok_exc = self._detok_exc, None
            raise exc

    def _emit(self, pairs):
        for rid, tok in pairs:
            self._detok_q.put((rid, tok))

    # --------------------------------------------------------- device side
    def submit(self, req: Request):
        self.metrics.on_submit(req.rid)
        self.backlog.put(req)
        self._pending += 1
        self.metrics.set_backlog(self.backlog.qsize())

    def _admit_some(self):
        """Fill free slots from the backlog — at most one prefill call, at
        most ``prefill_group`` requests, shipped even if the group is
        short (straggler tolerance)."""
        eng = self.engine
        room = min(len(eng.free_slots()), eng.cfg.prefill_group)
        batch = []
        while room > 0 and not self.backlog.empty():
            batch.append(self.backlog.get_nowait())
            room -= 1
        if batch:
            t_admit = now()
            pairs = eng.admit([(r.rid, r.tokens, r.max_new)
                               for r in batch])
            t_first = now()
            bucket = eng.bucket_for(max(len(r.tokens) for r in batch))
            for r in batch:
                self.metrics.on_admitted(r.rid, bucket, t_admit, t_first)
            self._emit(pairs)

    def pump(self) -> bool:
        """One scheduling round: admit, then one decode step across slots.
        Returns False when there is nothing left to do. Re-raises a
        detokenize-callback failure recorded by the drain thread."""
        self._raise_detok()
        eng = self.engine
        with TELEMETRY.span("serve.pump", backlog=self.backlog.qsize()):
            self._admit_some()
            if eng.active:
                self._emit(eng.step())
        for _rid, _toks in eng.drain_finished():
            self._pending -= 1
        self.metrics.set_backlog(self.backlog.qsize())
        return eng.active > 0 or not self.backlog.empty()

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Offline batch: submit everything, pump to completion, join the
        detokenize thread's queue, return per-request token lists."""
        for r in requests:
            self.submit(r)
        while self._pending > 0:
            self.pump()
        self._detok_q.join()  # all handed tokens consumed by the thread
        self._raise_detok()   # a failure in the final drain still surfaces
        return self.outputs

    def stats(self) -> Dict:
        """Engine stats + the per-request lifecycle summaries this
        scheduler fed (queue wait / TTFT percentiles, detok_errors)."""
        st = self.engine.stats() if hasattr(self.engine, "stats") else {}
        st["requests"] = self.metrics.request_summary()
        return st

    def close(self):
        self._detok_q.put(_STOP)
        self._detok_thread.join(timeout=5)
        self._raise_detok()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        # don't mask an in-flight exception with the detok re-raise
        if exc and exc[0] is not None:
            self._detok_q.put(_STOP)
            self._detok_thread.join(timeout=5)
            return False
        self.close()
        return False
