"""Machine-readable serve-capability probe.

One predicate shared by ``launch/quantize`` (``--serve`` / ``--serve-smoke``
skip paths) and ``benchmarks.run --only serve`` so a model that cannot be
served degrades the same way everywhere: a ``(False, reason)`` with a
stable ``key:detail`` reason string, never a silent ``print``-and-skip and
never a vanished bench row (mirroring the ``recon/sharded`` fallback
contract).

Reasons:
  ``no_decode_path:<family>``        model has no ``decode_step``
  ``unsupported_family:<family>``    slot engine needs the transformer
                                     KV layout (dense / moe / vlm)
  ``unsupported_layout:mla``         MLA's latent cache has no per-head
                                     int8 layout and no vector-pos decode
  ``kv_quant_unsupported:<family>``  family cannot hold an int8 KV cache
"""
from __future__ import annotations

from typing import Tuple

OK = "ok"
ENGINE_FAMILIES = ("dense", "moe", "vlm")


def serve_capability(model, *, engine: bool = False,
                     kv_quant: bool = False) -> Tuple[bool, str]:
    """Can ``model`` be served? ``engine=False`` asks only for the plain
    uniform-batch decode loop (``serve_smoke``); ``engine=True`` asks for
    the slot-based continuous-batching engine."""
    cfg = model.cfg
    family = getattr(cfg, "family", "?")
    if not hasattr(model, "decode_step"):
        return False, f"no_decode_path:{family}"
    if not engine:
        # encdec *does* support kv_quant; only state-space families lack a
        # KV cache entirely, and MLA's latent layout has no per-head scales
        if kv_quant and family in ("ssm", "hybrid"):
            return False, f"kv_quant_unsupported:{family}"
        if kv_quant and getattr(cfg, "use_mla", False):
            return False, "kv_quant_unsupported:mla"
        return True, OK
    if family not in ENGINE_FAMILIES:
        return False, f"unsupported_family:{family}"
    if getattr(cfg, "use_mla", False):
        return False, "unsupported_layout:mla"
    return True, OK
