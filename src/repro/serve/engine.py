"""Continuous-batching serving engine: bucketed AOT prefill + slot decode.

The engine is the production loop around the kernel-backed deploy path
(ROADMAP Open item #1, modeled on MaxText's offline inference engine):

* **Bucketed AOT prefill** — one executable per power-of-two length bucket,
  compiled ahead of time with ``jax.jit(...).lower(...).compile()``. A
  prompt is right-padded to its bucket; under the causal mask the padded
  keys contribute exactly zero at real positions, leaving only XLA
  reduction-order rounding (~1e-6; the parity test pins the envelope and
  exact greedy tokens per bucket), so bucketing costs padded FLOPs, never
  accuracy. Each prefill call packs up to ``prefill_group`` prompts of
  *different* true lengths into one batch; short groups are padded with
  dummy rows whose slot id is out of bounds, so the scatter drops them —
  group size never changes the traced shape.

* **Slot-based decode** — a fixed ``[slots, max_len]`` KV state stepped by
  a single compiled ``decode_step`` with a donated carry. Each slot keeps
  its own position; finished slots go inactive in place and are re-filled
  by the next prefill without touching the compiled graph. After
  ``__init__``, ``compile_count`` is frozen: occupancy, request count, and
  bucket mix never retrace (pinned by quantlint's ``no_retrace`` guard in
  tier-1).

* **int8 KV cache by default** (``kv_quant=True``) — quantize-on-append
  via :mod:`repro.serve.kv`, attention reads the codes directly
  (dequant-free), HBM per slot drops ~3.5x vs f32 / ~1.8x vs bf16, which
  is what converts FlexRound's weight-memory win into concurrent users.

Greedy decoding with a fixed ``max_new`` per request (offline/benchmark
serving — no early EOS release, which would need per-request stop state on
device). The host side (admission queue, detokenize thread) lives in
:mod:`repro.serve.scheduler`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import profiler
from repro.obs.serve_metrics import ServeMetrics
from repro.obs.sink import current_manifest
from repro.obs.telemetry import TELEMETRY, Stopwatch
from repro.serve import kv as skv
from repro.serve.smoke import serve_capability


@dataclass
class EngineConfig:
    slots: int = 4
    max_len: int = 128
    prefill_group: int = 2   # prompts packed into one prefill call
    kv_quant: bool = True    # int8 KV cache (the serving default)
    min_bucket: int = 8
    dtype: Any = None        # fp KV dtype when kv_quant=False

    def buckets(self) -> List[int]:
        """Power-of-two prefill buckets up to the largest <= max_len."""
        out, b = [], self.min_bucket
        while b <= self.max_len:
            out.append(b)
            b *= 2
        if not out:
            raise ValueError(
                f"max_len={self.max_len} below min_bucket={self.min_bucket}")
        return out


@dataclass
class SlotView:
    """Host-side mirror of one device slot (no sync needed to read it)."""
    rid: Optional[int] = None
    remaining: int = 0
    emitted: List[int] = field(default_factory=list)


# ------------------------------------------------------- traced functions
# Module-level builders so the jaxpr analyzers (repro.analysis.trace) can
# jit + trace the exact functions the engine compiles, without standing up
# a full engine: serve_prefill/serve_decode TracedEntrys run QL201 (dead
# scale invars), QL203 (donated KV-carry aliasing) and QL303 (subnormal
# KV scales) over the same graphs production serves from.

def init_state(model, cfg: EngineConfig):
    """Fresh slot state — the donated carry every compiled call threads."""
    cache = model.init_cache(cfg.slots, cfg.max_len, dtype=cfg.dtype,
                             kv_quant=cfg.kv_quant)
    return {
        "cache": cache,
        "tokens": jnp.zeros((cfg.slots, 1), jnp.int32),
        "pos": jnp.zeros((cfg.slots,), jnp.int32),
        "remaining": jnp.zeros((cfg.slots,), jnp.int32),
    }


def _greedy(model, last, params):
    logit_mult = getattr(model.cfg, "logit_mult", 1.0)
    logits = (last @ model.lm_head(params).astype(last.dtype)) * logit_mult
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def make_prefill(model, ctx, cfg: EngineConfig, bucket: int):
    """One bucket's prefill-insert: prefill a (group, bucket) batch into a
    fresh cache, scatter it into the slot state, emit the first token."""
    G = cfg.prefill_group

    def prefill_insert(params, state, tokens, true_len, slot_ids, max_new):
        """tokens (G, bucket) right-padded; slot_ids==slots marks a
        dummy row — every scatter below drops it, so a half-empty
        admission group traces identically to a full one."""
        fresh = model.init_cache(G, bucket, dtype=cfg.dtype,
                                 kv_quant=cfg.kv_quant)
        last, fresh = model.prefill(params, tokens, fresh, ctx,
                                    true_len=true_len)
        first = _greedy(model, last, params)  # (G,)
        cache = state["cache"]
        for nm in fresh:
            cache[nm] = cache[nm].at[:, slot_ids, :bucket].set(
                fresh[nm].astype(cache[nm].dtype), mode="drop")
        state["cache"] = cache
        state["tokens"] = state["tokens"].at[slot_ids].set(
            first[:, None], mode="drop")
        state["pos"] = state["pos"].at[slot_ids].set(
            true_len, mode="drop")
        state["remaining"] = state["remaining"].at[slot_ids].set(
            jnp.maximum(max_new - 1, 0), mode="drop")
        return state, first
    return prefill_insert


def make_decode(model, ctx, cfg: EngineConfig):
    """The single decode step across all slots (active-masked).

    Only the KV cache is a donated carry: it is the buffer whose reuse
    pays (and it is consumed exactly once, by the layer scan). The
    per-slot bookkeeping vectors (``meta``: tokens/pos/remaining, a few
    ints per slot) are read by several equations each — donating them
    would be a QL203 aliasing hazard for no memory win — so they are
    threaded undonated.
    """
    def decode(params, cache, meta):
        active = meta["remaining"] > 0
        logits, cache = model.decode_step(
            params, meta["tokens"], cache, meta["pos"], ctx)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        emitted = jnp.where(active, nxt, -1)
        return cache, {
            "tokens": jnp.where(active[:, None], nxt[:, None],
                                meta["tokens"]),
            "pos": meta["pos"] + active,
            "remaining": meta["remaining"] - active,
        }, emitted
    return decode


class ServeEngine:
    """Fixed-capacity continuous-batching engine over one model + ctx.

    Raises ``KVQuantUnsupported`` (machine-readable ``reason``) for model
    families the slot layout cannot serve — same contract the benchmarks
    and ``launch/quantize --serve`` degrade through instead of crashing.
    """

    def __init__(self, model, params, ctx, config: EngineConfig = None):
        self.cfg = config or EngineConfig()
        ok, reason = serve_capability(model, engine=True,
                                      kv_quant=self.cfg.kv_quant)
        if not ok:
            raise skv.KVQuantUnsupported(reason, f"{model.cfg.name}: cannot "
                                         "build a slot-based serve engine")
        self.model = model
        self.params = params
        self.ctx = ctx
        self.buckets = self.cfg.buckets()
        self.compile_count = 0
        # per-bucket prefill latency histograms + request lifecycle metrics
        # (host-side, always on; the old prefill_us[bucket] scalar overwrote,
        # so only the last call per bucket survived)
        self.metrics = ServeMetrics()
        self.decode_steps = 0
        self.tokens_emitted = 0
        self.slots: List[SlotView] = [SlotView()
                                      for _ in range(self.cfg.slots)]
        self._finished: List[Tuple[int, List[int]]] = []
        self._build()

    # ------------------------------------------------------------ compile
    def _build(self):
        model, ctx, c = self.model, self.ctx, self.cfg
        G = c.prefill_group
        decode = make_decode(model, ctx, c)

        self.state = init_state(model, c)
        sds = lambda x: jax.tree.map(  # noqa: E731
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), x)
        p_s, st_s = sds(self.params), sds(self.state)
        i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731

        self._prefill_exec = {}
        self.compile_us: Dict[str, float] = {}
        with TELEMETRY.span("serve.build", buckets=len(self.buckets)):
            for b in self.buckets:
                sw = Stopwatch()
                self._prefill_exec[b] = (
                    jax.jit(make_prefill(model, ctx, c, b),
                            donate_argnums=(1,))
                    .lower(p_s, st_s, i32(G, b), i32(G), i32(G), i32(G))
                    .compile())
                self.compile_count += 1
                self.compile_us[f"prefill_b{b}"] = sw.elapsed_us()
            cache_s = sds(self.state["cache"])
            meta_s = sds({k: self.state[k]
                          for k in ("tokens", "pos", "remaining")})
            sw = Stopwatch()
            self._decode_exec = (jax.jit(decode, donate_argnums=(1,))
                                 .lower(p_s, cache_s, meta_s).compile())
            self.compile_count += 1
            self.compile_us["decode"] = sw.elapsed_us()

    # ------------------------------------------------------------ serving
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.rid is None]

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds the largest bucket "
                         f"{self.buckets[-1]} (max_len={self.cfg.max_len})")

    def admit(self, requests: Sequence[Tuple[int, np.ndarray, int]],
              ) -> List[Tuple[int, int]]:
        """Prefill up to ``prefill_group`` requests into free slots.

        ``requests``: (rid, prompt tokens (int32 1-D), max_new). Returns
        the (rid, first generated token) pairs — the prefill logits already
        yield token #1, so a request costs ``1 prefill + (max_new - 1)``
        decode steps. One compiled call regardless of group fill.
        """
        c = self.cfg
        G = c.prefill_group
        free = self.free_slots()
        if not requests:
            return []
        if len(requests) > min(G, len(free)):
            raise ValueError(f"admit got {len(requests)} requests for "
                             f"{len(free)} free slots, group {G}")
        lens = [len(t) for _, t, _ in requests]
        bucket = self.bucket_for(max(lens))
        tokens = np.zeros((G, bucket), np.int32)
        true_len = np.ones((G,), np.int32)  # dummy rows: gather at index 0
        slot_ids = np.full((G,), c.slots, np.int32)  # out of bounds = drop
        max_new = np.zeros((G,), np.int32)
        for row, (rid, toks, mn) in enumerate(requests):
            n = lens[row]
            if n + mn > c.max_len:
                mn = c.max_len - n  # clamp: KV writes must stay in range
            tokens[row, :n] = toks
            true_len[row] = n
            slot_ids[row] = free[row]
            max_new[row] = max(mn, 1)
        sw = Stopwatch()
        with TELEMETRY.span("serve.prefill", bucket=bucket,
                            group=len(requests)):
            self.state, first = self._prefill_exec[bucket](
                self.params, self.state, tokens, true_len, slot_ids, max_new)
            first = np.asarray(first)  # host sync: first tokens are needed
        self.metrics.observe_prefill(bucket, sw.elapsed_us())
        out = []
        for row, (rid, _, _) in enumerate(requests):
            s = self.slots[slot_ids[row]]
            s.rid, s.remaining, s.emitted = rid, int(max_new[row]) - 1, []
            tok = int(first[row])
            s.emitted.append(tok)
            self.tokens_emitted += 1
            out.append((rid, tok))
            if s.remaining == 0:  # max_new=1: the prefill token was it
                self._finished.append((rid, s.emitted))
                self.metrics.on_finished(rid)
                self.slots[slot_ids[row]] = SlotView()
        self.metrics.set_occupancy(self.active)
        return out

    def step(self) -> List[Tuple[int, int]]:
        """One decode step across all slots; returns (rid, token) pairs for
        slots that were active. Frees slots whose budget is exhausted."""
        meta = {k: self.state[k] for k in ("tokens", "pos", "remaining")}
        sw = Stopwatch()
        with TELEMETRY.span("serve.decode_step", active=self.active), \
                profiler.annotate("serve.decode_step", self.decode_steps):
            cache, meta, emitted = self._decode_exec(
                self.params, self.state["cache"], meta)
            self.state = {"cache": cache, **meta}
            emitted = np.asarray(emitted)  # host sync: tokens are consumed
        step_us = sw.elapsed_us()
        self.decode_steps += 1
        out = []
        for i, s in enumerate(self.slots):
            if s.rid is None:
                continue
            tok = int(emitted[i])
            s.emitted.append(tok)
            s.remaining -= 1
            self.tokens_emitted += 1
            out.append((s.rid, tok))
            if s.remaining <= 0:
                self._finished.append((s.rid, s.emitted))
                self.metrics.on_finished(s.rid)
                self.slots[i] = SlotView()
        self.metrics.observe_decode(step_us, len(out))
        self.metrics.set_occupancy(self.active)
        return out

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.rid is not None)

    def drain_finished(self) -> List[Tuple[int, List[int]]]:
        done, self._finished = self._finished, []
        return done

    # ------------------------------------------------------------ metrics
    def hbm_per_slot_bytes(self) -> int:
        """Bytes of KV state one slot pins, from the live cache pytree —
        the one accessor the bench row and quantlint's QL403 both read."""
        return skv.hbm_per_slot_bytes(self.state["cache"], self.cfg.slots)

    def hbm_per_slot_mib(self) -> float:
        return self.hbm_per_slot_bytes() / 2**20

    def stats(self) -> Dict[str, Any]:
        """Drain point for the engine's metrics. ``prefill_us`` is a
        per-bucket histogram summary ({count, mean, p50, p95, max} —
        every admit counts, not just the last one per bucket);
        ``requests`` carries the per-request lifecycle summaries (queue
        wait / TTFT / decode-step percentiles, occupancy, backlog,
        detok_errors); ``manifest`` stamps the run identity."""
        return {
            "compile_count": self.compile_count,
            "buckets": list(self.buckets),
            "prefill_us": self.metrics.prefill_summary(),
            "decode_steps": self.decode_steps,
            "tokens_emitted": self.tokens_emitted,
            "hbm_per_slot_bytes": self.hbm_per_slot_bytes(),
            "hbm_per_slot_MiB": self.hbm_per_slot_mib(),
            "kv_quant": self.cfg.kv_quant,
            "requests": self.metrics.request_summary(),
            "manifest": current_manifest().brief(),
        }
