"""repro: FlexRound (ICML 2023) as a production-grade JAX PTQ framework."""
__version__ = "1.0.0"
