"""repro: FlexRound (ICML 2023) as a production-grade JAX PTQ framework."""
import jax

# Sharding-invariant RNG, required for data-parallel calibration: with the
# legacy (non-partitionable) threefry, random draws whose outputs are sharded
# (QDrop masks over a dp-sharded minibatch) produce *different values* than
# the same program on one device, so a sharded reconstruction could never
# reproduce the unsharded trajectory. The partitionable scheme generates each
# shard's bits independently yet identically to the single-device stream —
# no collectives, same values under any sharding. Newer jax releases default
# to True; pinning it here keeps every entry point (train, PTQ, benchmarks,
# tests) on one stream. This is an intended trajectory change relative to
# the legacy stream: the recon fixtures were re-recorded under it (see
# tests/fixtures/record_fixtures.py).
jax.config.update("jax_threefry_partitionable", True)

__version__ = "1.0.0"
