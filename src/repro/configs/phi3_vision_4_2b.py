"""phi-3-vision-4.2b — phi3-mini backbone; CLIP frontend STUBBED [hf:microsoft/Phi-3-vision-128k-instruct; hf].

input_specs feeds precomputed patch embeddings (B, n_patches, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064, norm="rmsnorm",
    act="swiglu", frontend="vision_stub", n_patches=256)
