"""Registry over the 10 assigned architecture configs (one module each)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, reduced
from repro.configs import (
    deepseek_v3_671b,
    granite_3_2b,
    llama4_scout_17b_a16e,
    mamba2_130m,
    olmo_1b,
    phi3_vision_4_2b,
    qwen2_5_14b,
    recurrentgemma_2b,
    smollm_135m,
    whisper_medium,
)

_MODULES = (
    qwen2_5_14b, smollm_135m, granite_3_2b, olmo_1b, recurrentgemma_2b,
    llama4_scout_17b_a16e, deepseek_v3_671b, mamba2_130m, whisper_medium,
    phi3_vision_4_2b,
)

_ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_IDS = tuple(_ARCHS)


def get_config(name: str) -> ArchConfig:
    try:
        return _ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")


def get_smoke_config(name: str) -> ArchConfig:
    return reduced(get_config(name))
