"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8, MTP [arXiv:2412.19437; hf].

Assignment d_ff=2048 is the per-expert FF width (moe_d_ff); the 3 leading
dense layers use the published 18432. MLA decode uses the weight-absorbed
latent-cache form.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, d_ff=18432, vocab=129280, n_experts=256,
    top_k=8, n_shared_experts=1, moe_d_ff=2048, first_dense=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
    qk_rope_dim=64, v_head_dim=128, head_dim=192, mtp=True, act="swiglu",
    moe_group=128, capacity_factor=1.25)
