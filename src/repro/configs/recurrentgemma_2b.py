"""recurrentgemma-2b — RG-LRU + local attention, pattern RRA [arXiv:2402.19427; hf].

Sub-quadratic (O(1) recurrent state + 2048-token window) => runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
    local_window=2048, layer_pattern="RRA", lru_width=2560, act="geglu",
    norm="rmsnorm", sub_quadratic=True)
