"""granite-3-2b — GQA + muP-style multipliers [hf:ibm-granite/granite-3.0-2b-base; hf].

vocab 49155 is NOT divisible by the model mesh axis (16): embedding/lm_head
shard along d_model instead (see launch/sharding.py).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, norm="rmsnorm",
    act="swiglu", emb_mult=12.0, resid_mult=0.22, logit_mult=1.0 / 8.0,
    tie_embeddings=True)
