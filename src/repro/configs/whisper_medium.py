"""whisper-medium — enc-dec backbone; conv/audio frontend STUBBED [arXiv:2212.04356; unverified].

input_specs feeds precomputed frame embeddings (B, S_enc, d_model).
vocab 51865 not divisible by 16 => embed/head shard along d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865, enc_layers=24,
    norm="layernorm", act="gelu", frontend="audio_stub")
