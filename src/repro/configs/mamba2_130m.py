"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free, O(1) decode state => runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768, n_heads=0,
    n_kv_heads=0, d_ff=0, vocab=50280, ssm_state=128, ssm_conv=4,
    ssm_expand=2, ssm_headdim=64, sub_quadratic=True, attn_chunk=256)
