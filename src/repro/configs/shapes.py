"""The assigned input-shape suite (applies to every architecture).

  train_4k     seq 4,096   x batch 256   -> lowers train_step
  prefill_32k  seq 32,768  x batch 32    -> lowers prefill (serve)
  decode_32k   seq 32,768  x batch 128   -> lowers serve_step (1 new token,
                                            KV cache of seq_len)
  long_500k    seq 524,288 x batch 1     -> serve_step; ONLY for
                                            sub-quadratic archs (ssm/hybrid)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_IDS = tuple(SHAPES)


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def cell_applicable(cfg, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch x shape) runs; reason if skipped (per assignment)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic attention (skip noted in DESIGN.md)")
    return True, ""
