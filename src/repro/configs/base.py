"""ArchConfig: one dataclass describing every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention / norms / acts
    attn_bias: bool = False          # qwen-style QKV bias
    rope_theta: float = 10000.0
    local_window: int = 0            # sliding-window size (0 = global)
    norm: str = "rmsnorm"            # rmsnorm | layernorm | layernorm_nonparam
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    sub_quadratic: bool = False      # supports long_500k decode

    # granite-style muP multipliers
    emb_mult: float = 1.0
    resid_mult: float = 1.0
    logit_mult: float = 1.0

    # MoE
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense: int = 0             # leading dense layers (deepseek)
    capacity_factor: float = 1.25
    moe_group: int = 2048

    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mtp: bool = False                # multi-token-prediction head

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64

    # hybrid (recurrentgemma): layer pattern string, e.g. "RRA"
    layer_pattern: str = ""
    lru_width: int = 0

    # encoder-decoder (whisper)
    enc_layers: int = 0

    # modality frontend stub (audio/vision): inputs include precomputed embeds
    frontend: str = "none"           # none | audio_stub | vision_stub
    n_patches: int = 0               # vision_stub: patches per image

    # numerics / execution
    dtype: str = "bfloat16"
    attn_chunk: int = 1024
    remat: bool = True
    xent_chunk: int = 512

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> Tuple[int, int]:
        """(total, active) parameter estimates for MODEL_FLOPS."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        Dh = self.head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            d_in = self.ssm_expand * D
            per = D * 2 * d_in + d_in * D + d_in * (2 * self.ssm_state + 2)
            tot = emb + L * per
            return tot, tot
        attn = D * (self.n_heads * Dh) * 2 + D * (self.n_kv_heads * Dh) * 2
        if self.use_mla:
            r, rq = self.kv_lora_rank, self.q_lora_rank
            dn, dr, dv = self.qk_nope_dim, self.qk_rope_dim, self.v_head_dim
            H = self.n_heads
            attn = (D * rq + rq * H * (dn + dr) + D * (r + dr)
                    + r * H * (dn + dv) + H * dv * D)
        mlp_mult = 3 if self.act == "swiglu" else 2
        dense_mlp = mlp_mult * D * F
        if self.is_moe:
            moe_mlp = mlp_mult * D * self.moe_d_ff
            shared = mlp_mult * D * self.moe_d_ff * self.n_shared_experts
            n_moe = L - self.first_dense
            tot = (emb + L * attn + self.first_dense * dense_mlp
                   + n_moe * (self.n_experts * moe_mlp + shared + D * self.n_experts))
            act = (emb + L * attn + self.first_dense * dense_mlp
                   + n_moe * (self.top_k * moe_mlp + shared + D * self.n_experts))
            return tot, act
        n_attn_layers = L + self.enc_layers
        tot = emb + n_attn_layers * (attn + dense_mlp)
        if self.enc_layers:  # cross attention in decoder
            tot += L * attn
        if self.family == "hybrid":
            # RG-LRU blocks replace attention in R layers: approx same size
            pass
        return tot, tot


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Shrink a config to smoke-test size, preserving structure."""
    n_layers = {"hybrid": 3}.get(cfg.family, 2)
    if cfg.first_dense:
        n_layers = 2  # one dense + one moe
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=max(n_layers, 2 if cfg.enc_layers else n_layers),
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128,
        vocab=128,
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        moe_group=64,
        attn_chunk=32,
        xent_chunk=32,
        remat=False,
        dtype="float32",
    )
    if cfg.is_moe:
        # capacity_factor=8 makes the reduced config dropless so decode vs
        # full-forward consistency is exact (production keeps 1.25 + drops)
        changes.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=64,
                       first_dense=min(cfg.first_dense, 1),
                       capacity_factor=8.0)
    if cfg.use_mla:
        changes.update(q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16,
                       qk_rope_dim=8, v_head_dim=16, head_dim=24)
    if cfg.family == "ssm":
        changes.update(ssm_state=16, ssm_headdim=16)
    if cfg.family == "hybrid":
        changes.update(layer_pattern=cfg.layer_pattern, lru_width=64)
    if cfg.enc_layers:
        changes.update(enc_layers=2)
    if cfg.n_patches:
        changes.update(n_patches=8)
    return dataclasses.replace(cfg, **changes)
