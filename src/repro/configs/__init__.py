from repro.configs.base import ArchConfig, reduced  # noqa: F401
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
from repro.configs.shapes import (  # noqa: F401
    SHAPE_IDS,
    SHAPES,
    ShapeSpec,
    cell_applicable,
    get_shape,
)
