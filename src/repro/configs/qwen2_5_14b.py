"""qwen2.5-14b — dense GQA + QKV bias [hf:Qwen/Qwen2.5-*; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=13824, vocab=152064, attn_bias=True,
    rope_theta=1e6, norm="rmsnorm", act="swiglu")
