"""llama4-scout-17b-a16e — MoE 16e top-1 + shared expert [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Modeled as full-attention (chunked-attention variant not modeled) => skips
long_500k; vision early-fusion out of scope for the text backbone cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048, n_experts=16, top_k=1,
    n_shared_experts=1, moe_d_ff=8192, rope_theta=5e5, act="swiglu",
    moe_group=1024)
