"""LSQ/LSQ+ activation quantizer (Esser et al., 2020), as used by the paper
for the activation step size during BRECQ/QDrop-setting reconstruction.

    x̂ = s * clip( round( (x - β) / s ), qmin, qmax ) + β

``s`` (step) and ``β`` (offset; LSQ+) are learned with the LSQ gradient scale
g = 1 / sqrt(numel * qmax) applied via a forward-identity trick. Activations
are quantized on the fly (dynamic graph position, static learned step).
"""
from __future__ import annotations

import sys
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import method_api
from repro.core import quantizer as qz
from repro.core.quant_config import QuantConfig

EPS = 1e-8


def init(x_sample: jax.Array, qcfg: QuantConfig) -> Dict[str, jax.Array]:
    x32 = x_sample.astype(jnp.float32)
    if qcfg.symmetric:
        step = jnp.maximum(jnp.max(jnp.abs(x32)) / qcfg.qmax, EPS)
        beta = jnp.float32(0.0)
    else:
        lo, hi = jnp.min(x32), jnp.max(x32)
        lo, hi = jnp.minimum(lo, 0.0), jnp.maximum(hi, 0.0)
        step = jnp.maximum((hi - lo) / (qcfg.qmax - qcfg.qmin), EPS)
        beta = lo
    return {"step": step.reshape(()), "beta": jnp.asarray(beta, jnp.float32).reshape(())}


def apply(x: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig) -> jax.Array:
    g = 1.0 / jnp.sqrt(jnp.float32(x.size) * qcfg.qmax)
    s = qz.grad_scale(state["step"], g)
    b = qz.grad_scale(state["beta"], g)
    x32 = x.astype(jnp.float32)
    q = jnp.clip(qz.ste_round((x32 - b) / s), qcfg.qmin, qcfg.qmax)
    return (s * q + b).astype(x.dtype)


def deploy_astate(state: Dict[str, jax.Array], qcfg: QuantConfig):
    """Static int8 activation params for the W8A8 serving kernel.

    Returns ``(a_scale, a_zero)`` with ``a_zero`` the *unsigned* zero point
    on the [0, 255] grid, or None when the LSQ grid has no exact 8-bit
    integer form (bits != 8). The learned offset β is snapped to the step
    grid (z = round(-β/s)), so the kernel's integer codes reproduce the
    trained fake-quant up to that sub-step shift:

      asymmetric (qmin=0):  x̂ = s*(clip(round(x/s)+z, 0, 255) - z)
      symmetric:            z = 128 centers the signed grid (clip at -128
                            instead of LSQ's -127 on the extreme tail).
    """
    if qcfg.bits != 8:
        return None
    step = jnp.asarray(state["step"], jnp.float32)
    if qcfg.symmetric:
        zero = jnp.float32(128.0)
    else:
        zero = jnp.clip(jnp.round(-jnp.asarray(state["beta"], jnp.float32)
                                  / step), 0.0, 255.0)
    return step, zero


def trainable(state: Dict[str, jax.Array]) -> Dict[str, bool]:
    return {"step": True, "beta": True}


def project(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    out = dict(state)
    out["step"] = jnp.maximum(out["step"], EPS)
    return out


method_api.register_method("lsq", kind="activation")(sys.modules[__name__])
