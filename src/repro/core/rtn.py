"""Round-to-nearest (RTN): the no-learning PTQ baseline.

    Ŵ = s1 * ( clip( round(W / s1) + z, qmin, qmax ) - z )

with s1/z from the observer. Nothing is learnable.
"""
from __future__ import annotations

import sys
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import method_api, observers, qtensor
from repro.core import quantizer as qz
from repro.core.quant_config import QuantConfig


def init(w: jax.Array, qcfg: QuantConfig, key=None) -> Dict[str, jax.Array]:
    scale, zero = observers.init_scale(w, qcfg)
    return {"s1": scale.astype(jnp.float32), "zero": zero.astype(jnp.float32)}


def codes(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig,
          ste: bool = True) -> jax.Array:
    return qz.quantize(w, state["s1"], state["zero"], qcfg, ste=ste)


def apply(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig) -> jax.Array:
    return qz.fake_quant(w, state["s1"], state["zero"], qcfg, ste=True)


def loss_extra(state, qcfg, step, recipe) -> jax.Array:
    return jnp.float32(0.0)


def trainable(state: Dict[str, jax.Array]) -> Dict[str, bool]:
    return {k: False for k in state}


def project(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    return state


def export(w, state, qcfg: QuantConfig, dtype=jnp.bfloat16) -> qtensor.QTensor:
    q = qz.quantize(w, state["s1"], state["zero"], qcfg, ste=False)
    return qtensor.from_codes(q, state["s1"], state["zero"], qcfg, dtype=dtype)


method_api.register_method("rtn")(sys.modules[__name__])
