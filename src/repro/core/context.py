"""QuantCtx — the single integration point between models and quantization.

Every linear/conv in the model zoo routes through ``ctx.linear`` /
``ctx.conv2d``. Depending on ``mode`` the same model code runs:

  fp       plain full-precision math (pretraining, teacher stream)
  recon    weights fake-quantized via learnable rounding states, activations
           LSQ-fake-quantized (+QDrop random dropping)  -> PTQ reconstruction
  deploy   weights are QTensor leaves (int codes); every QTensor matmul
           dispatches through ``kernels/ops.qtensor_matmul`` under the
           ``backend`` policy below; activations statically quantized
           (no drop), and W8A8 sites feed the integer kernel directly
  calib    eager-only: record activation ranges per site (LSQ init)
  capture  eager-only: record per-site inputs (layer-wise reconstruction)

Deploy backend policy (see ``kernels.ops.resolve_backend``):

  auto     compiled Pallas kernels on TPU; XLA ref path elsewhere (default)
  pallas   Pallas kernels — compiled on TPU, interpreted off-TPU (parity
           testing); ``interpret`` can be forced explicitly
  xla      pure-jnp ref implementations (always compile, any backend)

Which QTensor shapes hit which kernel: 4-bit K-packed (d_in, d_out) weights
-> W4A16 dequant-matmul; 8-bit weights with static LSQ activation states ->
W8A8 integer matmul (activation codes computed from the LSQ step/offset);
8-bit weight-only -> W8A16 dequant-matmul; stacked expert weights
(E, d_in, d_out) with batch_dims=1 -> grid-extended per-expert
dequant-matmul. Conv QTensors still dequantize (no conv kernel yet).

Site names are stable strings ("layers.3.attn.wq"); QDrop RNG is derived per
site by folding a crc32 of the name into the step key.
"""
from __future__ import annotations

import dataclasses
import warnings
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import lsq, qdrop
from repro.core.qtensor import QTensor, dequantize_qtensor
from repro.core.quant_config import QuantRecipe, SitePlan


def site_key(key: jax.Array, name: str) -> jax.Array:
    return jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)


# Conv sites that already warned about the dequantize fallback (host-level,
# so a site warns once per process — not once per trace or per step).
_CONV_FALLBACK_WARNED: set = set()


def _warn_conv_fallback(name: str, qt: QTensor) -> None:
    if name in _CONV_FALLBACK_WARNED:
        return
    _CONV_FALLBACK_WARNED.add(name)
    from repro.core.qtensor import tree_weight_bytes
    warnings.warn(
        f"deploy conv site {name!r}: no conv kernel for QTensor shape "
        f"{qt.shape} ({qt.bits}-bit, {tree_weight_bytes(qt)} bytes) — "
        "dequantizing per call (correct but unaccelerated; see ROADMAP "
        "Serving path / the quantlint QL207 kernel-coverage report)",
        RuntimeWarning, stacklevel=3)


@dataclasses.dataclass
class QuantCtx:
    mode: str = "fp"
    recipe: Optional[QuantRecipe] = None
    wstates: Dict[str, Any] = dataclasses.field(default_factory=dict)
    astates: Dict[str, Any] = dataclasses.field(default_factory=dict)
    key: Optional[jax.Array] = None
    drop_enabled: bool = True
    # eager-only stores
    records: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # kernel backend for deploy mode: "auto" | "pallas" | "xla"
    backend: str = "auto"
    # Pallas interpret override; None resolves from the actual jax backend
    # (compiled on TPU, interpret elsewhere)
    interpret: Optional[bool] = None
    # Pre-resolved per-site plans (the reconstruction engine passes these so a
    # compiled step shared across blocks sees the right plan regardless of the
    # site-name strings baked into the trace); names missing from the mapping
    # fall back to recipe.resolve.
    plans: Optional[Dict[str, SitePlan]] = None
    # Per-site RNG salts as (traced) uint32 scalars. When set, QDrop keys are
    # derived by folding the salt instead of a crc32 constant of the name —
    # this keeps the compiled HLO identical across blocks while reproducing
    # the exact per-real-site-name key stream.
    site_salts: Optional[Dict[str, jax.Array]] = None

    # -------------------------------------------------------------- helpers
    def _plan(self, name: str, batch_dims: int = 0) -> Optional[SitePlan]:
        """Per-site plan (method + configs) from the recipe's rules."""
        if self.plans is not None and name in self.plans:
            return self.plans[name]
        if self.recipe is None:
            return None
        return self.recipe.resolve(name, batch_dims=batch_dims)

    def _site_key(self, name: str) -> jax.Array:
        if self.site_salts is not None and name in self.site_salts:
            return jax.random.fold_in(self.key, self.site_salts[name])
        return site_key(self.key, name)

    def _act(self, name: str, x: jax.Array) -> jax.Array:
        """Activation quantization before a linear (paper §4.3)."""
        if self.mode == "fp":
            return x
        if self.mode == "calib":
            x32 = x.astype(jnp.float32)
            lo = float(jnp.min(x32))
            hi = float(jnp.max(x32))
            if name in self.records:
                plo, phi = self.records[name]
                lo, hi = min(lo, plo), max(hi, phi)
            self.records[name] = (lo, hi)
            return x
        plan = self._plan(name)
        if plan is None or plan.act is None or name not in self.astates:
            return x
        x_hat = lsq.apply(x, self.astates[name], plan.act)
        if (self.mode == "recon" and self.recipe.setting == "qdrop"
                and self.drop_enabled and self.key is not None):
            return qdrop.qdrop(x, x_hat, self.recipe.drop_prob, self._site_key(name))
        return x_hat

    def _weight(self, name: str, w: Any, batch_dims: int) -> jax.Array:
        if isinstance(w, QTensor):
            return dequantize_qtensor(w)
        if self.mode == "recon" and name in self.wstates:
            plan = self._plan(name, batch_dims)
            return plan.method.apply(w, self.wstates[name], plan.weight)
        return w

    def _deploy_matmul(self, name: str, x: jax.Array, qt: QTensor,
                       batch_dims: int) -> jax.Array:
        """Serving-path matmul: every deploy-mode QTensor site dispatches
        through ``kernels/ops.qtensor_matmul`` under the backend policy.

        Any 2-D site with a trained 8-bit LSQ state hands the kernel the
        snapped integer activation grid (``lsq.deploy_astate``), not just
        the unpacked-W8 sites: W8A8 runs the true-integer kernel, W4A8 (and
        odd-shape sub-8-bit weights) fake-quantize activations on that same
        grid in front of the dequant kernel. Before, packed/sub-8-bit sites
        fell back to ``_act``'s training-time ``lsq.apply`` — close, but a
        different (un-snapped β) grid than the integer path, and the kernel
        API itself dropped ``a_state`` outright for them — so serving
        numerics now use one deploy grid for every activation-quantized
        site regardless of weight layout."""
        from repro.kernels import ops as kops
        a_state = None
        if batch_dims == 0:
            plan = self._plan(name)
            if (plan is not None and plan.act is not None
                    and name in self.astates):
                a_state = lsq.deploy_astate(self.astates[name], plan.act)
        if a_state is None:
            # no integer-activation grid for this site: quantize (or pass
            # through) activations the usual way, weight stays integer
            x = self._act(name, x)
        return kops.qtensor_matmul(x, qt, a_state=a_state,
                                   backend=self.backend,
                                   interpret=self.interpret)

    # ------------------------------------------------------------------ ops
    def get_weight(self, name: str, w: Any, batch_dims: int = 0) -> jax.Array:
        """Effective (fake-quant / dequantized) weight for custom einsums
        (e.g. MLA weight-absorbed decode)."""
        return self._weight(name, w, batch_dims)

    def linear(self, name: str, x: jax.Array, w: Any, b: Optional[jax.Array] = None,
               batch_dims: int = 0) -> jax.Array:
        """y = act_quant(x) @ weight_quant(w) + b.

        w: (d_in, d_out), or (E, d_in, d_out) with batch_dims=1: then x has
        shape (..., E, N, d_in) and the contraction is a per-expert matmul.
        """
        if self.mode == "capture":
            self.records.setdefault(name, []).append(x)
        if (self.mode == "deploy" and isinstance(w, QTensor)
                and batch_dims in (0, 1)):
            y = self._deploy_matmul(name, x, w, batch_dims)
        else:
            x_eff = self._act(name, x)
            w_eff = self._weight(name, w, batch_dims)
            if batch_dims == 0:
                y = x_eff @ w_eff.astype(x_eff.dtype)
            else:
                y = jnp.einsum("...eni,eio->...eno", x_eff,
                               w_eff.astype(x_eff.dtype))
        if b is not None:
            y = y + b.astype(y.dtype)
        return y

    def conv2d(self, name: str, x: jax.Array, w: Any, b: Optional[jax.Array] = None,
               stride=(1, 1), padding="SAME") -> jax.Array:
        """x: (N,H,W,Cin), w: (kh,kw,Cin,Cout). Deploy-mode conv QTensors
        dequantize (no Pallas conv kernel yet — see ROADMAP Serving path);
        each such site warns once per process with its shape and bytes."""
        if self.mode == "capture":
            self.records.setdefault(name, []).append(x)
        if self.mode == "deploy" and isinstance(w, QTensor):
            _warn_conv_fallback(name, w)
        x_eff = self._act(name, x)
        w_eff = self._weight(name, w, 0)
        y = jax.lax.conv_general_dilated(
            x_eff, w_eff.astype(x_eff.dtype), window_strides=stride, padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if b is not None:
            y = y + b.astype(y.dtype)
        return y


FP_CTX = QuantCtx(mode="fp")


def fp() -> QuantCtx:
    return QuantCtx(mode="fp")
