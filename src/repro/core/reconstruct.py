"""Block/layer-wise PTQ reconstruction engine (paper §3.1, §4).

Implements the sequential reconstruction the paper uses everywhere:

  for each block B (transformer layer, or single linear for layer-wise):
      y_fp = B_fp(x_fp)                      # teacher on the fp stream
      learn rounding states minimizing ||y_fp - B_q(x_q)||^2 (+AdaRound reg)
      finalize B -> integer weights; advance both streams

``x_fp`` is the full-precision activation stream; ``x_q`` the stream produced
by already-quantized predecessors (the X̃ of Eq. ||WX - Ŵ X̃||). Activation
quantizers (LSQ) are initialized from the student stream and co-trained with
the rounding states (paper: LSQ technique for the activation step size).

Execution model (the hot path — this loop runs iters × layers times):

  scan engine             The minibatch schedule (epoch keys + gather
      indices) is precomputed on device once per block, then chunks of K
      optimization steps run inside a single jitted ``jax.lax.scan`` —
      Adam moments, rounding states, LSQ states and the PRNG stream are
      threaded as the scan carry and loss/mse trajectories come back as
      stacked outputs. One dispatch per K steps instead of one per step,
      and no host-side gathers. The RNG stream is bit-identical to the
      removed per-iteration legacy loop; parity is pinned against recorded
      legacy trajectories in tests/fixtures/recon_legacy_trajectories.npz.

  compiled-step cache     Blocks are canonicalized (site names rewritten to
      position-based tokens, per-site QDrop salts passed as traced uint32
      scalars, resolved SitePlans attached to the ctx) so the L identical
      layers of a transformer hit one compiled step/teacher/student/
      recon_error instead of L. Cache keys combine the block's ``apply_key``
      (models stamp structurally identical layers with a shared token),
      the canonicalized site plans (``SitePlan.cache_key``) and the recipe.
      Carried states are de-aliased (constant-dedup can hand identical init
      buffers to several sites) so ``donate_argnums`` is safe on the scan.

  probe mode              The sensitivity prober (repro.allocate) rides the
      same engine cache: ``probe_teacher`` hands out the per-``apply_key``
      compiled teacher, ``engine_scope`` bounds the lifetime of engines a
      probe pass builds, and probe-step traces are counted in
      ``EngineStats.probe_compiles`` so tests can assert the probe pass
      compiles O(distinct apply_keys) steps, not O(sites).

Distribution (data-parallel calibration): pass ``mesh=`` to
``reconstruct_block`` / ``quantize_blocks`` (and ``probe_blocks`` in
repro.allocate). The engine then places the calibration streams — ``x_q``,
``y_fp`` and the optional per-sample loss weights — with the leading sample
axis sharded over the mesh's data axes (``launch/sharding.stream_sharding``;
sample counts that don't divide the data-parallel size degrade to
replication), constrains the gathered minibatches to the same spec inside
the scanned step, and replicates the rounding/Adam/LSQ carry states and the
minibatch schedule (``NamedSharding(mesh, P())``). The loss/MSE reductions
are means over the *global* batch, so under jit the rounding-state gradients
all-reduce (psum) over the data axes automatically and every device steps
identical replicated states. The mesh is part of the engine cache key:
blocks still compile once per ``apply_key``, and the sharded trajectory
reproduces the unsharded one (both pinned in tests/test_sharded_recon.py).
``sample_weight`` consumes ``data/pipeline.assemble_global_batch``'s loss
weight: samples from dropped host shards carry weight 0 and the objective
becomes the weighted global-batch mean, so gradient magnitude stays unbiased
under straggler dropping. Per-block state is checkpointed (see
repro/checkpoint) so a failed node restarts at the block boundary; see
quantize_blocks(resume_dir=...).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsq
from repro.core import paths as pth
from repro.core.context import QuantCtx
from repro.core.quant_config import QuantRecipe, SitePlan
from repro.obs import profiler
from repro.obs.telemetry import TELEMETRY, Stopwatch
from repro.optim.adam import AdamConfig, adam_init, adam_update

DEFAULT_CHUNK = 100  # scan steps fused into one jitted dispatch

# Per-site lr rules ride adam_update's per-leaf lr_scale tree, so the base
# config carries lr=1.0 and each leaf scales it by its plan's lr.
_W_BASE_CFG = AdamConfig(lr=1.0)


@dataclasses.dataclass
class Site:
    """One quantizable weight inside a block."""
    path: Tuple  # path of the leaf within the block's param subtree
    kind: str = "linear"  # linear | conv
    batch_dims: int = 0


@dataclasses.dataclass
class BlockHandle:
    """A reconstruction unit: params + apply(params, x, ctx) -> y.

    ``apply_key``: optional hashable token identifying the *computation* of
    ``apply`` independent of this block's parameter values and site-name
    strings. Blocks that stamp the same token (e.g. the L identical layers a
    model's ``quant_blocks`` emits in one call) share one compiled recon
    step/teacher/student. The token must be fresh per ``quant_blocks`` call —
    apply closures bake per-call constants (rope tables, encoder output) into
    the trace. ``None`` disables sharing (the engine still caches per block
    object).
    """
    name: str
    params: Any
    apply: Callable[[Any, jax.Array, QuantCtx], jax.Array]
    sites: Dict[str, Site]
    apply_key: Optional[Any] = None


def _empty_curve() -> np.ndarray:
    return np.zeros((0,), np.float32)


@dataclasses.dataclass
class BlockReport:
    name: str
    err_before: float
    err_after: float
    iters: int
    seconds: float
    engine: str = "scan"
    steps_per_s: float = 0.0
    # Per-step loss/MSE trajectories (stacked scan outputs). Real fields —
    # not stapled-on attributes — so report serialization round-trips them.
    loss_curve: Any = dataclasses.field(default_factory=_empty_curve)
    mse_curve: Any = dataclasses.field(default_factory=_empty_curve)

    _CURVES = ("loss_curve", "mse_curve")

    def to_json(self) -> dict:
        """JSON-safe dict: trajectories as float lists (checkpoint meta)."""
        d = dataclasses.asdict(self)
        for k in self._CURVES:
            d[k] = np.asarray(getattr(self, k), np.float32).tolist()
        return d

    @classmethod
    def from_json(cls, d: dict) -> "BlockReport":
        """Inverse of ``to_json``, tolerating report-schema drift: unknown
        keys from a newer writer are dropped, missing keys fall back to the
        field defaults."""
        known = {f.name for f in dataclasses.fields(cls)}
        kept = {k: v for k, v in d.items() if k in known}
        for k in cls._CURVES:
            if k in kept:
                kept[k] = np.asarray(kept[k], np.float32)
        return cls(**kept)


# ------------------------------------------------------------- engine stats
@dataclasses.dataclass
class EngineStats:
    """Trace/compile counters (incremented at jit trace time, so each count
    is an actual XLA compilation, not a call)."""
    step_compiles: int = 0
    schedule_compiles: int = 0
    teacher_compiles: int = 0
    student_compiles: int = 0
    recon_error_compiles: int = 0
    probe_compiles: int = 0  # sensitivity-probe steps (repro.allocate)
    engine_builds: int = 0
    engine_hits: int = 0

    @property
    def compile_count(self) -> int:
        return (self.step_compiles + self.schedule_compiles +
                self.teacher_compiles + self.student_compiles +
                self.recon_error_compiles + self.probe_compiles)


_STATS = EngineStats()


def engine_stats() -> EngineStats:
    return _STATS


def reset_engine_stats() -> EngineStats:
    """Zero the counters (benchmarks/tests). The compiled-step cache itself
    is NOT cleared — pair with ``clear_engine_cache`` to measure cold."""
    for f in dataclasses.fields(EngineStats):
        setattr(_STATS, f.name, f.default)
    return _STATS


def clear_engine_cache() -> None:
    _ENGINE_CACHE.clear()
    _batch_schedule.clear_cache()


@contextlib.contextmanager
def engine_scope():
    """Evict engines built inside the scope when it exits.

    ``quantize_blocks`` and the sensitivity prober (repro.allocate) wrap
    their runs in this: their blocks' ``apply_key`` tokens are fresh per
    call, so entries built under the scope can never hit again, yet their
    apply closures pin per-call constants (rope tables, encoder outputs, the
    model itself). Entries that existed before the scope are untouched."""
    _SCOPE_STACK.append(set())
    try:
        yield
    finally:
        for k in _SCOPE_STACK.pop():
            _ENGINE_CACHE.pop(k, None)


def site_plans(block: BlockHandle, recipe: QuantRecipe) -> Dict[str, SitePlan]:
    """Resolve the recipe's rules once per block: site name -> SitePlan."""
    return {name: recipe.resolve(name, site)
            for name, site in block.sites.items()}


def init_wstates(block: BlockHandle, recipe: QuantRecipe) -> Dict[str, Any]:
    out = {}
    for name, site in block.sites.items():
        plan = recipe.resolve(name, site)
        w = pth.get_path(block.params, site.path)
        out[name] = plan.method.init(w, plan.weight)
    return out


def init_astates(block: BlockHandle, recipe: QuantRecipe, x_q: jax.Array,
                 prev: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """LSQ init from observed ranges on the student stream (eager pass).

    Per-site rules apply here too: a site whose plan has ``act is None``
    (weight-only override) gets no LSQ state and stays fp. Plans are resolved
    *first*: when every site of this block resolves to ``act is None`` the
    calibration forward pass is skipped entirely.
    """
    states = dict(prev or {})
    plans = site_plans(block, recipe)
    if all(p.act is None for p in plans.values()):
        return states
    ctx = QuantCtx(mode="calib", recipe=recipe)
    block.apply(block.params, x_q, ctx)
    for name, (lo, hi) in ctx.records.items():
        plan = plans.get(name) or recipe.resolve(name)
        if plan.act is None:
            continue
        sample = jnp.asarray([lo, hi], jnp.float32)
        states[name] = lsq.init(sample, plan.act)
    return states


def _trainable_mask(wstates, astates, plans: Dict[str, SitePlan]):
    wmask = {k: plans[k].method.trainable(v) for k, v in wstates.items()}
    amask = {k: lsq.trainable(v) for k, v in astates.items()}
    return wmask, amask


def _apply_mask(grads, mask):
    return jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g), grads, mask)


# ----------------------------------------------------------- step math
def _make_step_fn(apply_fn: Callable, recipe: QuantRecipe,
                  plans: Dict[str, SitePlan], a_opt_cfg: AdamConfig):
    """Single optimization step (traced inside the engine's scan body).

    ``plans`` keys the same namespace as the state dicts (the engine passes
    canonical position tokens, see _RenameCtx). Sites may carry
    heterogeneous plans (method, bits, lr): each site's rounding state is
    updated by its own method, all inside one tree-wide Adam update whose
    per-leaf lr_scale carries the rule-overridden learning rates.

    ``sw`` (optional, leading-sample-axis weights from
    ``assemble_global_batch``) turns the MSE into a weighted global-batch
    mean — dropped-shard samples carry weight 0, so the straggler policy's
    B / weight.sum() loss rescale happens here. ``sw=None`` keeps the plain
    ``jnp.mean`` bit-identical to the recorded trajectories.
    """

    def loss_fn(params, wstates, astates, x_q, y_fp, sw, step, key, salts):
        ctx = QuantCtx(mode="recon", recipe=recipe, wstates=wstates,
                       astates=astates, key=key, plans=plans, site_salts=salts)
        y = apply_fn(params, x_q, ctx)
        se = jnp.square(y.astype(jnp.float32) - y_fp.astype(jnp.float32))
        if sw is None:
            mse = jnp.mean(se)
        else:
            per = jnp.mean(se.reshape(se.shape[0], -1), axis=1)
            w = sw.astype(jnp.float32)
            mse = jnp.sum(per * w) / jnp.maximum(jnp.sum(w), 1e-9)
        reg = jnp.float32(0.0)
        for name, st in wstates.items():
            plan = plans[name]
            reg = reg + plan.method.loss_extra(st, plan.weight, step, recipe)
        return mse + reg, mse

    def step_fn(params, wstates, astates, wopt, aopt, x_q, y_fp, sw, step,
                key, salts):
        (loss, mse), (gw, ga) = jax.value_and_grad(loss_fn, argnums=(1, 2),
                                                   has_aux=True)(
            params, wstates, astates, x_q, y_fp, sw, step, key, salts)
        wmask, amask = _trainable_mask(wstates, astates, plans)
        gw = _apply_mask(gw, wmask)
        w_lr = {k: jax.tree.map(lambda _: plans[k].lr, v)
                for k, v in wstates.items()}
        wstates, wopt, _ = adam_update(gw, wopt, wstates, _W_BASE_CFG,
                                       lr_scale=w_lr)
        wstates = {k: plans[k].method.project(v) for k, v in wstates.items()}
        if astates:
            ga = _apply_mask(ga, amask)
            astates, aopt, _ = adam_update(ga, aopt, astates, a_opt_cfg)
            astates = {k: lsq.project(v) for k, v in astates.items()}
        return wstates, astates, wopt, aopt, loss, mse

    return step_fn


def recon_error(block: BlockHandle, recipe: QuantRecipe, wstates, astates,
                x_q, y_fp) -> float:
    ctx = QuantCtx(mode="recon", recipe=recipe, wstates=wstates, astates=astates,
                   key=jax.random.key(recipe.seed), drop_enabled=False)
    y = block.apply(block.params, x_q, ctx)
    return float(jnp.mean(jnp.square(y.astype(jnp.float32) - y_fp.astype(jnp.float32))))


# ------------------------------------------------- canonicalization + cache
class _RenameCtx:
    """Ctx proxy translating model-side site names to canonical tokens.

    The model's apply closure bakes real site-name strings ("layers.3.wq");
    translating them at the ctx boundary lets one compiled step serve every
    structurally identical block: state dicts, plan lookups and QDrop salt
    lookups all key on the canonical token. Names outside the mapping pass
    through untouched (they hold no rounding/LSQ state here, so they stay fp).
    """
    __slots__ = ("_ctx", "_map")

    def __init__(self, ctx: QuantCtx, mapping: Dict[str, str]):
        self._ctx = ctx
        self._map = mapping

    def linear(self, name, *args, **kwargs):
        return self._ctx.linear(self._map.get(name, name), *args, **kwargs)

    def conv2d(self, name, *args, **kwargs):
        return self._ctx.conv2d(self._map.get(name, name), *args, **kwargs)

    def get_weight(self, name, *args, **kwargs):
        return self._ctx.get_weight(self._map.get(name, name), *args, **kwargs)

    def __getattr__(self, item):
        return getattr(self._ctx, item)


def _canon_names(block: BlockHandle) -> Dict[str, str]:
    """real site name -> position-based canonical token (sorted order, so
    structurally identical blocks map corresponding sites to the same
    token)."""
    return {rn: f"~s{i}" for i, rn in enumerate(sorted(block.sites))}


def _salt(name: str) -> jax.Array:
    # must match context.site_key's crc32 constant so scanned and legacy
    # engines consume the identical QDrop key stream
    return jnp.uint32(zlib.crc32(name.encode()) & 0x7FFFFFFF)


@dataclasses.dataclass
class _Engine:
    """Compiled callables for one equivalence class of blocks. Holds a strong
    ref to the exemplar apply fn so id()-keyed cache entries stay valid."""
    apply: Callable
    run_chunk: Callable
    teacher: Callable
    student: Callable
    recon_err: Callable


_ENGINE_CACHE: "collections.OrderedDict[Any, _Engine]" = collections.OrderedDict()
_ENGINE_CACHE_MAX = 64
# Engines built inside a quantize_blocks call are evicted when it returns:
# apply_key tokens are fresh per quant_blocks call, so those entries can
# never hit again, yet their closures pin per-call constants (rope tables,
# encoder outputs, the model itself). Entries from direct reconstruct_block
# use stay in the bounded LRU.
_SCOPE_STACK: List[set] = []


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _batch_schedule(key, iters: int, n: int, bs: int):
    """Epoch key/minibatch-index schedule, built on device in one dispatch.

    Replays the legacy loop's RNG exactly: per step ``key, k1, k2 =
    split(key, 3)``, gather indices drawn with ``choice(k1, n, (bs,),
    replace=False)``. Full-batch recon (bs == n) skips the gather tensor
    entirely — the engine reuses x_q/y_fp as-is.
    """
    _STATS.schedule_compiles += 1

    def split3(k, _):
        k, k1, k2 = jax.random.split(k, 3)
        return k, (k1, k2)

    _, (k1s, k2s) = jax.lax.scan(split3, key, None, length=iters)
    if bs == n:
        return None, k2s
    idx = jax.vmap(
        lambda k: jax.random.choice(k, n, (bs,), replace=False))(k1s)
    return idx, k2s


def _engine_key(block: BlockHandle, recipe: QuantRecipe,
                plans: Dict[str, SitePlan], canon: Dict[str, str],
                mesh=None):
    akey = (block.apply_key if block.apply_key is not None
            else ("~obj", id(block.apply)))
    sites = tuple(sorted(
        (canon[rn], s.kind, s.batch_dims, plans[rn].cache_key())
        for rn, s in block.sites.items()))
    # run_chunk closures bake the mesh (minibatch sharding constraints), so
    # the same block under a different mesh needs a distinct engine
    return (akey, sites, recipe, mesh)


def _constrain_stream(x, mesh):
    """Pin a leading-sample-axis tensor to the data-parallel stream spec
    (inside a trace, so the shape is static)."""
    from repro.launch.sharding import stream_sharding
    return jax.lax.with_sharding_constraint(x, stream_sharding(mesh,
                                                               x.shape[0]))


def _build_engine(block: BlockHandle, recipe: QuantRecipe,
                  plans_c: Dict[str, SitePlan],
                  mapping: Dict[str, str], mesh=None) -> _Engine:
    block_apply = block.apply

    def apply_c(p, x, ctx):
        return block_apply(p, x, _RenameCtx(ctx, mapping))

    a_opt_cfg = AdamConfig(lr=recipe.lr_lsq)
    step = _make_step_fn(apply_c, recipe, plans_c, a_opt_cfg)

    def run_chunk(params, wstates, astates, wopt, aopt, x_q, y_fp,
                  idx, k2s, steps, salts, sweight):
        _STATS.step_compiles += 1
        if mesh is not None:
            # carried states are replicated; the gather below re-shards the
            # minibatch over the data axes so the per-step loss is a mean
            # over the global batch (gradients psum automatically)
            from repro.launch.sharding import replicated
            repl = replicated(mesh)
            wstates, astates, wopt, aopt = jax.tree.map(
                lambda a: jax.lax.with_sharding_constraint(a, repl),
                (wstates, astates, wopt, aopt))

        def body(carry, xs):
            ws, as_, wo, ao = carry
            if idx is None:
                k2, stp = xs
                xb, yb, wb = x_q, y_fp, sweight
            else:
                ix, k2, stp = xs
                xb = jnp.take(x_q, ix, axis=0)
                yb = jnp.take(y_fp, ix, axis=0)
                wb = None if sweight is None else jnp.take(sweight, ix,
                                                           axis=0)
                if mesh is not None:
                    xb = _constrain_stream(xb, mesh)
                    yb = _constrain_stream(yb, mesh)
            ws, as_, wo, ao, loss, mse = step(params, ws, as_, wo, ao,
                                              xb, yb, wb, stp, k2, salts)
            return (ws, as_, wo, ao), (loss, mse)

        xs = (k2s, steps) if idx is None else (idx, k2s, steps)
        carry, traj = jax.lax.scan(body, (wstates, astates, wopt, aopt), xs)
        return (*carry, *traj)

    def teacher(params, x):
        _STATS.teacher_compiles += 1
        return apply_c(params, x, QuantCtx(mode="fp"))

    def student(params, x, astates):
        _STATS.student_compiles += 1
        ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates,
                       plans=plans_c)
        return apply_c(params, x, ctx)

    def recon_err(params, wstates, astates, x_q, y_fp):
        _STATS.recon_error_compiles += 1
        ctx = QuantCtx(mode="recon", recipe=recipe, wstates=wstates,
                       astates=astates, key=jax.random.key(recipe.seed),
                       drop_enabled=False, plans=plans_c)
        y = apply_c(params, x_q, ctx)
        return jnp.mean(jnp.square(y.astype(jnp.float32) -
                                   y_fp.astype(jnp.float32)))

    # Carried states are de-aliased before the first chunk, so donation is
    # safe (the old "same buffer twice" rejection came from constant-dedup
    # aliasing identical init buffers across sites).
    return _Engine(
        apply=block_apply,
        run_chunk=jax.jit(run_chunk, donate_argnums=(1, 2, 3, 4)),
        teacher=jax.jit(teacher),
        student=jax.jit(student),
        recon_err=jax.jit(recon_err),
    )


def _get_engine(block: BlockHandle, recipe: QuantRecipe,
                plans: Dict[str, SitePlan], mesh=None
                ) -> Tuple[_Engine, Dict[str, str]]:
    canon = _canon_names(block)
    key = _engine_key(block, recipe, plans, canon, mesh)
    eng = _ENGINE_CACHE.get(key)
    if eng is not None:
        _STATS.engine_hits += 1
        _ENGINE_CACHE.move_to_end(key)
        return eng, canon
    eng = _build_engine(block, recipe,
                        {canon[rn]: plans[rn] for rn in block.sites}, canon,
                        mesh)
    _STATS.engine_builds += 1
    _ENGINE_CACHE[key] = eng
    if _SCOPE_STACK:
        _SCOPE_STACK[-1].add(key)
    while len(_ENGINE_CACHE) > _ENGINE_CACHE_MAX:
        _ENGINE_CACHE.popitem(last=False)
    return eng, canon


def _dealias(*trees):
    """Copy every leaf into its own freshly materialized buffer. JAX
    constant-dedup can hand several sites the same underlying buffer for
    identical init arrays (e.g. all-zero zero points); XLA rejects donating
    one buffer twice, so the carried states get unique storage before
    entering the donated scan."""
    return tuple(jax.tree.map(lambda x: jnp.array(x, copy=True), t)
                 for t in trees)


# ----------------------------------------------------------------- engines
def _place_sharded(mesh, x_q, y_fp, sample_weight, state_trees):
    """Device placement for a sharded run: calibration streams over the data
    axes on the leading sample axis, everything the scan carries replicated.
    All arrays end up committed to the same mesh so jitted calls never mix
    device sets."""
    from repro.launch.sharding import replicated, stream_sharding
    stream = stream_sharding(mesh, x_q.shape[0])
    x_q = jax.device_put(x_q, stream)
    y_fp = jax.device_put(y_fp, stream)
    if sample_weight is not None:
        sample_weight = jax.device_put(sample_weight, stream)
    state_trees = jax.device_put(state_trees, replicated(mesh))
    return x_q, y_fp, sample_weight, state_trees


def _run_scan(block: BlockHandle, recipe: QuantRecipe,
              plans: Dict[str, SitePlan], wstates, astates_all, x_q, y_fp,
              key, chunk: int, mesh=None, sample_weight=None):
    """Scan-fused engine: returns (wstates, astates_all, err0, err1,
    loop_seconds, loss_curve, mse_curve)."""
    eng, canon = _get_engine(block, recipe, plans, mesh)
    inv = {c: r for r, c in canon.items()}
    c_w = {canon[r]: v for r, v in wstates.items()}
    c_a = {canon[r]: astates_all[r] for r in block.sites if r in astates_all}
    salts = {canon[r]: _salt(r) for r in block.sites}

    a_opt_cfg = AdamConfig(lr=recipe.lr_lsq)
    wopt = adam_init(c_w, _W_BASE_CFG)
    aopt = adam_init(c_a, a_opt_cfg)
    c_w, c_a, wopt, aopt = _dealias(c_w, c_a, wopt, aopt)

    n = x_q.shape[0]
    bs = min(recipe.batch_size, n)
    idx, k2s = _batch_schedule(key, recipe.iters, n, bs)
    steps = jnp.arange(recipe.iters, dtype=jnp.int32)
    if mesh is not None:
        x_q, y_fp, sample_weight, placed = _place_sharded(
            mesh, x_q, y_fp, sample_weight,
            (c_w, c_a, wopt, aopt, salts, idx, k2s, steps))
        c_w, c_a, wopt, aopt, salts, idx, k2s, steps = placed

    # err0 runs on the (possibly mesh-placed) states but outside the timed
    # window: loop_s / steps_per_s measure the optimization loop itself
    err0 = float(eng.recon_err(block.params, c_w, c_a, x_q, y_fp))

    chunk = max(1, min(chunk, recipe.iters))
    sw = Stopwatch()
    losses, mses = [], []
    it, n_chunk = 0, 0
    while it < recipe.iters:
        sl = slice(it, it + min(chunk, recipe.iters - it))
        # host-side span around the compiled dispatch: the traced run_chunk
        # jaxpr is identical with telemetry on or off (tier-1 pins zero
        # added compiles). sync= folds device completion into the span so
        # per-chunk time is honest, matching the block_until_ready below.
        with TELEMETRY.span("recon.chunk", block=block.name, start=it,
                            steps=sl.stop - it) as sp, \
                profiler.annotate("recon.chunk", n_chunk):
            c_w, c_a, wopt, aopt, lo, ms = eng.run_chunk(
                block.params, c_w, c_a, wopt, aopt, x_q, y_fp,
                None if idx is None else idx[sl], k2s[sl], steps[sl], salts,
                sample_weight)
            sp.block_on(ms)
        losses.append(lo)
        mses.append(ms)
        it = sl.stop
        n_chunk += 1
    if mses:
        jax.block_until_ready(mses[-1])
    loop_s = sw.elapsed_s()

    err1 = float(eng.recon_err(block.params, c_w, c_a, x_q, y_fp))
    w_out = {inv[c]: v for c, v in c_w.items()}
    a_out = dict(astates_all)
    a_out.update({inv[c]: v for c, v in c_a.items()})
    return (w_out, a_out, err0, err1, loop_s,
            jnp.concatenate(losses) if losses else jnp.zeros((0,)),
            jnp.concatenate(mses) if mses else jnp.zeros((0,)))


def reconstruct_block(block: BlockHandle, recipe: QuantRecipe, x_q: jax.Array,
                      y_fp: jax.Array, key: jax.Array,
                      astates: Optional[Dict[str, Any]] = None, *,
                      chunk: int = DEFAULT_CHUNK, mesh=None,
                      sample_weight: Optional[jax.Array] = None,
                      ) -> Tuple[Dict[str, Any], Dict[str, Any], BlockReport]:
    """Optimize rounding (+LSQ) states for one block. Returns final states.

    Runs the fused, compile-cached device loop. The RNG stream matches the
    removed per-iteration legacy loop bit-for-bit (trajectory parity is
    pinned against recorded fixtures in tests/test_recon_engine.py). The
    report carries the measured loop throughput (``steps_per_s``) and the
    loss/mse trajectories (``rep.loss_curve`` / ``rep.mse_curve``, stacked
    device arrays).

    ``mesh``: optional ``jax.sharding.Mesh`` — calibration tensors are
    sharded over the mesh's data axes on the leading sample axis and the
    optimization states replicated (see the module docstring; the RNG stream
    and trajectories match the unsharded run). ``sample_weight``: optional
    (N,) per-sample loss weights (``assemble_global_batch``), consumed as a
    weighted global-batch mean; None keeps the plain mean bit-identical to
    the recorded trajectories.
    """
    sw = Stopwatch()
    with TELEMETRY.span("recon.block", block=block.name, iters=recipe.iters):
        plans = site_plans(block, recipe)
        wstates = init_wstates(block, recipe)
        astates = astates if astates is not None else init_astates(
            block, recipe, x_q)

        wstates, astates, err0, err1, loop_s, loss_curve, mse_curve = \
            _run_scan(block, recipe, plans, wstates, astates, x_q, y_fp,
                      key, chunk, mesh, sample_weight)

    return wstates, astates, BlockReport(
        block.name, err0, err1, recipe.iters, sw.elapsed_s(),
        steps_per_s=recipe.iters / max(loop_s, 1e-9),
        loss_curve=loss_curve, mse_curve=mse_curve)


def finalize_block(block: BlockHandle, recipe: QuantRecipe, wstates,
                   as_qtensor: bool = True) -> Any:
    """Replace quantized leaves with QTensor (deploy) or dequant arrays.

    Each site exports with its own plan, so one block may hold QTensors of
    different bit-widths (mixed-precision recipes)."""
    from repro.core.qtensor import dequantize_qtensor
    params = block.params
    for name, site in block.sites.items():
        plan = recipe.resolve(name, site)
        w = pth.get_path(params, site.path)
        qt = plan.method.export(w, wstates[name], plan.weight, dtype=w.dtype)
        params = pth.set_path(params, site.path, qt if as_qtensor else
                              dequantize_qtensor(qt))
    return params


# --------------------------------------------------------------- probe entry
def probe_teacher(block: BlockHandle, recipe: QuantRecipe, mesh=None):
    """Compiled teacher for sensitivity-probe passes (repro.allocate).

    Shares the engine cache, so the L structurally identical blocks of a
    transformer compile one teacher. Call inside ``engine_scope()`` — probe
    passes build engines whose closures pin per-call constants. ``mesh``
    keys the engine like the recon entry points, so a sharded probe pass
    stays compile-flat under the same cache."""
    eng, _ = _get_engine(block, recipe, site_plans(block, recipe), mesh)
    return eng.teacher


def count_probe_compile() -> None:
    """Called by probe-step traces at trace time (repro.allocate), so
    ``engine_stats().probe_compiles`` counts actual XLA compilations."""
    _STATS.probe_compiles += 1


# --------------------------------------------------------------------- driver
def _explode_layerwise(block: BlockHandle, recipe: QuantRecipe, x_q):
    """Yield per-site sub-blocks for recon='layer' (AdaRound-style).

    Each site becomes a standalone linear/conv reconstruction problem whose
    inputs are captured from the block execution — one capture pass records
    every site's input, reused for all yielded sub-blocks.
    """
    ctx_q = QuantCtx(mode="capture", recipe=recipe)
    block.apply(block.params, x_q, ctx_q)
    for name, site in block.sites.items():
        x_site = ctx_q.records[name][0]
        w = pth.get_path(block.params, site.path)

        if site.kind == "conv":
            def apply_fn(p, x, ctx, _n=name):
                return ctx.conv2d(_n, x, p["w"])
        elif site.batch_dims:
            def apply_fn(p, x, ctx, _n=name, _bd=site.batch_dims):
                return ctx.linear(_n, x, p["w"], batch_dims=_bd)
        else:
            def apply_fn(p, x, ctx, _n=name):
                return ctx.linear(_n, x, p["w"])

        sub = BlockHandle(name=f"{block.name}/{name}", params={"w": w},
                          apply=apply_fn,
                          sites={name: Site(path=("w",), kind=site.kind,
                                            batch_dims=site.batch_dims)},
                          apply_key=("~layerwise", site.kind, site.batch_dims))
        yield name, site, sub, x_site


def quantize_blocks(blocks: List[BlockHandle], recipe: QuantRecipe,
                    x0: jax.Array, key: Optional[jax.Array] = None,
                    as_qtensor: bool = True,
                    checkpoint_dir: Optional[str] = None,
                    progress: Optional[Callable[[str], None]] = None, *,
                    chunk: int = DEFAULT_CHUNK,
                    allocation: Optional[dict] = None,
                    mesh=None,
                    sample_weight: Optional[jax.Array] = None,
                    ) -> Tuple[List[Any], Dict[str, Any], List[BlockReport]]:
    """Sequentially quantize a chain of blocks (the paper's full procedure).

    Returns (per-block finalized params, astates, reports). If
    ``checkpoint_dir`` is set, per-block state is saved after each block and
    a crashed run resumes at the first un-finalized block. Teacher/student/
    recon-step compilations are shared across structurally identical blocks
    (see ``BlockHandle.apply_key``).

    ``allocation``: optional summary of the bit allocation that emitted the
    recipe's rules (``AllocationReport.meta()`` from repro.allocate). It is
    recorded in every per-block checkpoint; a resume whose recipe or
    allocation no longer matches fails loudly, naming the allocation.

    ``mesh``: optional ``jax.sharding.Mesh`` for data-parallel calibration —
    the activation streams (x_fp / x_q / teacher outputs) are sharded over
    the mesh's data axes on the leading sample axis, optimization states
    replicated; trajectories match the unsharded run (module docstring).
    ``sample_weight``: optional (N,) per-sample loss weights aligned with
    ``x0``'s leading axis (``assemble_global_batch``'s straggler mask).
    """
    with engine_scope():
        # engines built here are released on exit: their apply closures pin
        # per-call constants and their apply_key tokens can never hit again
        return _quantize_blocks(blocks, recipe, x0, key, as_qtensor,
                                checkpoint_dir, progress, chunk, allocation,
                                mesh, sample_weight)


def _quantize_blocks(blocks, recipe, x0, key, as_qtensor, checkpoint_dir,
                     progress, chunk, allocation, mesh=None,
                     sample_weight=None):
    key = key if key is not None else jax.random.key(recipe.seed)
    ckpt = None
    if checkpoint_dir is not None:
        from repro.checkpoint.checkpoint import PTQCheckpointer
        ckpt = PTQCheckpointer(checkpoint_dir)

    if mesh is not None:
        from repro.launch.sharding import stream_sharding
        x0 = jax.device_put(x0, stream_sharding(mesh, x0.shape[0]))

    x_fp = x0
    x_q = x0
    astates: Dict[str, Any] = {}
    finalized: List[Any] = []
    reports: List[BlockReport] = []

    start = 0
    if ckpt is not None:
        resumed = ckpt.load(blocks, recipe, allocation=allocation)
        if resumed is not None:
            start, finalized, astates, reports, x_fp, x_q = resumed
            if mesh is not None:
                # checkpointed streams come back as single-device arrays;
                # re-place them or the resumed run loses the sharding (and
                # recompiles every engine for the replicated layout)
                from repro.launch.sharding import stream_sharding
                x_fp = jax.device_put(x_fp,
                                      stream_sharding(mesh, x_fp.shape[0]))
                x_q = jax.device_put(x_q,
                                     stream_sharding(mesh, x_q.shape[0]))

    def advance_student(block, eng, canon, params, x):
        a_c = {canon[r]: astates[r] for r in block.sites if r in astates}
        return eng.student(params, x, a_c)

    for i in range(len(blocks)):
        block = blocks[i]
        eng, canon = _get_engine(block, recipe, site_plans(block, recipe),
                                 mesh)
        y_fp = eng.teacher(block.params, x_fp)
        if i < start:
            # replay streams from checkpointed finalized params
            x_q = advance_student(block, eng, canon, finalized[i], x_q)
            x_fp = y_fp
            continue
        key, bkey = jax.random.split(key)
        astates = init_astates(block, recipe, x_q, prev=astates)

        if recipe.recon == "layer":
            wstates_all: Dict[str, Any] = {}
            for name, site, sub, x_site in _explode_layerwise(block, recipe,
                                                              x_q):
                sub_eng, _ = _get_engine(sub, recipe, site_plans(sub, recipe),
                                         mesh)
                y_site = sub_eng.teacher(sub.params, x_site)
                # fold the site's identity into the key: sibling sites must
                # draw independent minibatch schedules (sharing bkey gave
                # every site of a block the same gather indices)
                skey = jax.random.fold_in(bkey, _salt(name))
                ws, a_sub, rep = reconstruct_block(sub, recipe, x_site, y_site,
                                                   skey, astates=dict(astates),
                                                   chunk=chunk, mesh=mesh,
                                                   sample_weight=sample_weight)
                astates.update(a_sub)
                wstates_all[name] = ws[name]
                reports.append(rep)
            wstates = wstates_all
        else:
            wstates, astates, rep = reconstruct_block(block, recipe, x_q, y_fp,
                                                      bkey, astates=astates,
                                                      chunk=chunk, mesh=mesh,
                                                      sample_weight=sample_weight)
            reports.append(rep)

        new_params = finalize_block(block, recipe, wstates, as_qtensor=as_qtensor)
        finalized.append(new_params)
        x_q = advance_student(block, eng, canon, new_params, x_q)
        x_fp = y_fp
        if progress:
            progress(f"[{i + 1}/{len(blocks)}] {block.name} "
                     f"err {reports[-1].err_before:.3e} -> {reports[-1].err_after:.3e}")
        if ckpt is not None:
            plan_meta = [{n: p.summary()
                          for n, p in site_plans(b, recipe).items()}
                         for b in blocks[:i + 1]]
            ckpt.save(i + 1, finalized, astates, reports, x_fp, x_q,
                      plans=plan_meta, engine="scan", allocation=allocation)

    return finalized, astates, reports
