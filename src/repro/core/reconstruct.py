"""Block/layer-wise PTQ reconstruction engine (paper §3.1, §4).

Implements the sequential reconstruction the paper uses everywhere:

  for each block B (transformer layer, or single linear for layer-wise):
      y_fp = B_fp(x_fp)                      # teacher on the fp stream
      learn rounding states minimizing ||y_fp - B_q(x_q)||^2 (+AdaRound reg)
      finalize B -> integer weights; advance both streams

``x_fp`` is the full-precision activation stream; ``x_q`` the stream produced
by already-quantized predecessors (the X̃ of Eq. ||WX - Ŵ X̃||). Activation
quantizers (LSQ) are initialized from the student stream and co-trained with
the rounding states (paper: LSQ technique for the activation step size).

Distribution: all jitted functions here are pjit-compatible — calibration
tensors carry a leading sample axis that the caller shards over the data mesh
axis; gradients reduce via the standard pjit psum. Per-block state is
checkpointed (see repro/checkpoint) so a failed node restarts at the block
boundary; see quantize_blocks(resume_dir=...).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lsq, methods
from repro.core import paths as pth
from repro.core.context import QuantCtx
from repro.core.quant_config import QuantRecipe
from repro.optim.adam import AdamConfig, adam_init, adam_update


@dataclasses.dataclass
class Site:
    """One quantizable weight inside a block."""
    path: Tuple  # path of the leaf within the block's param subtree
    kind: str = "linear"  # linear | conv
    batch_dims: int = 0


@dataclasses.dataclass
class BlockHandle:
    """A reconstruction unit: params + apply(params, x, ctx) -> y."""
    name: str
    params: Any
    apply: Callable[[Any, jax.Array, QuantCtx], jax.Array]
    sites: Dict[str, Site]


@dataclasses.dataclass
class BlockReport:
    name: str
    err_before: float
    err_after: float
    iters: int
    seconds: float


def _qcfg_for(recipe: QuantRecipe, site: Site):
    import dataclasses as dc
    c = recipe.weight_qconfig()
    return dc.replace(c, batch_dims=site.batch_dims) if site.batch_dims else c


def init_wstates(block: BlockHandle, recipe: QuantRecipe) -> Dict[str, Any]:
    method = methods.get(recipe.method)
    out = {}
    for name, site in block.sites.items():
        w = pth.get_path(block.params, site.path)
        out[name] = method.init(w, _qcfg_for(recipe, site))
    return out


def init_astates(block: BlockHandle, recipe: QuantRecipe, x_q: jax.Array,
                 prev: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """LSQ init from observed ranges on the student stream (eager pass)."""
    aq = recipe.act_qconfig()
    if aq is None:
        return {}
    ctx = QuantCtx(mode="calib", recipe=recipe)
    block.apply(block.params, x_q, ctx)
    states = dict(prev or {})
    for name, (lo, hi) in ctx.records.items():
        sample = jnp.asarray([lo, hi], jnp.float32)
        states[name] = lsq.init(sample, aq)
    return states


def _trainable_mask(wstates, astates, recipe: QuantRecipe):
    method = methods.get(recipe.method)
    wmask = {k: method.trainable(v) for k, v in wstates.items()}
    amask = {k: lsq.trainable(v) for k, v in astates.items()}
    return wmask, amask


def _apply_mask(grads, mask):
    return jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g), grads, mask)


def make_recon_step(block: BlockHandle, recipe: QuantRecipe,
                    w_opt_cfg: AdamConfig, a_opt_cfg: AdamConfig):
    """Builds the jitted (wstates, astates, opts, batch, step, key) -> ... fn."""
    method = methods.get(recipe.method)

    def loss_fn(wstates, astates, x_q, y_fp, step, key):
        ctx = QuantCtx(mode="recon", recipe=recipe, wstates=wstates,
                       astates=astates, key=key)
        y = block.apply(block.params, x_q, ctx)
        mse = jnp.mean(jnp.square(y.astype(jnp.float32) - y_fp.astype(jnp.float32)))
        reg = jnp.float32(0.0)
        for name, st in wstates.items():
            reg = reg + method.loss_extra(st, _qcfg_for(recipe, block.sites[name]),
                                          step, recipe)
        return mse + reg, mse

    def step_fn(wstates, astates, wopt, aopt, x_q, y_fp, step, key):
        (loss, mse), (gw, ga) = jax.value_and_grad(loss_fn, argnums=(0, 1),
                                                   has_aux=True)(
            wstates, astates, x_q, y_fp, step, key)
        wmask, amask = _trainable_mask(wstates, astates, recipe)
        gw = _apply_mask(gw, wmask)
        wstates, wopt, _ = adam_update(gw, wopt, wstates, w_opt_cfg)
        wstates = {k: method.project(v) for k, v in wstates.items()}
        if astates:
            ga = _apply_mask(ga, amask)
            astates, aopt, _ = adam_update(ga, aopt, astates, a_opt_cfg)
            astates = {k: lsq.project(v) for k, v in astates.items()}
        return wstates, astates, wopt, aopt, loss, mse

    # NOTE: no donation — rounding states are small, and JAX constant-dedup
    # can alias identical init buffers (e.g. zero points) across sites, which
    # makes donation reject with "same buffer twice".
    return jax.jit(step_fn)


def recon_error(block: BlockHandle, recipe: QuantRecipe, wstates, astates,
                x_q, y_fp) -> float:
    ctx = QuantCtx(mode="recon", recipe=recipe, wstates=wstates, astates=astates,
                   key=jax.random.key(recipe.seed), drop_enabled=False)
    y = block.apply(block.params, x_q, ctx)
    return float(jnp.mean(jnp.square(y.astype(jnp.float32) - y_fp.astype(jnp.float32))))


def reconstruct_block(block: BlockHandle, recipe: QuantRecipe, x_q: jax.Array,
                      y_fp: jax.Array, key: jax.Array,
                      astates: Optional[Dict[str, Any]] = None,
                      ) -> Tuple[Dict[str, Any], Dict[str, Any], BlockReport]:
    """Optimize rounding (+LSQ) states for one block. Returns final states."""
    t0 = time.time()
    wstates = init_wstates(block, recipe)
    astates = astates if astates is not None else init_astates(block, recipe, x_q)
    err0 = recon_error(block, recipe, wstates, astates, x_q, y_fp)

    w_opt_cfg = AdamConfig(lr=recipe.lr)
    a_opt_cfg = AdamConfig(lr=recipe.lr_lsq)
    wopt = adam_init(wstates, w_opt_cfg)
    aopt = adam_init(astates, a_opt_cfg)
    step_fn = make_recon_step(block, recipe, w_opt_cfg, a_opt_cfg)

    n = x_q.shape[0]
    bs = min(recipe.batch_size, n)

    @jax.jit
    def sample(key):
        return jax.random.choice(key, n, (bs,), replace=False)

    for it in range(recipe.iters):
        key, k1, k2 = jax.random.split(key, 3)
        idx = sample(k1)
        xb = jnp.take(x_q, idx, axis=0)
        yb = jnp.take(y_fp, idx, axis=0)
        wstates, astates, wopt, aopt, loss, mse = step_fn(
            wstates, astates, wopt, aopt, xb, yb, jnp.int32(it), k2)

    err1 = recon_error(block, recipe, wstates, astates, x_q, y_fp)
    rep = BlockReport(block.name, err0, err1, recipe.iters, time.time() - t0)
    return wstates, astates, rep


def finalize_block(block: BlockHandle, recipe: QuantRecipe, wstates,
                   as_qtensor: bool = True) -> Any:
    """Replace quantized leaves with QTensor (deploy) or dequant arrays."""
    from repro.core.qtensor import dequantize_qtensor
    method = methods.get(recipe.method)
    params = block.params
    for name, site in block.sites.items():
        w = pth.get_path(params, site.path)
        qt = method.export(w, wstates[name], _qcfg_for(recipe, site), dtype=w.dtype)
        params = pth.set_path(params, site.path, qt if as_qtensor else
                              dequantize_qtensor(qt))
    return params


# --------------------------------------------------------------------- driver
def _teacher_fn(block: BlockHandle):
    return jax.jit(lambda p, x: block.apply(p, x, QuantCtx(mode="fp")))


def _student_fn(block: BlockHandle, recipe: QuantRecipe):
    def f(p, x, astates):
        ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates)
        return block.apply(p, x, ctx)
    return jax.jit(f)


def _explode_layerwise(block: BlockHandle, recipe: QuantRecipe, x_q):
    """Yield per-site sub-blocks for recon='layer' (AdaRound-style).

    Each site becomes a standalone linear/conv reconstruction problem whose
    inputs are captured from the (partially quantized) block execution.
    """
    for name, site in block.sites.items():
        ctx_q = QuantCtx(mode="capture", recipe=recipe)
        block.apply(block.params, x_q, ctx_q)
        x_site = ctx_q.records[name][0]
        w = pth.get_path(block.params, site.path)

        if site.kind == "conv":
            def apply_fn(p, x, ctx, _n=name):
                return ctx.conv2d(_n, x, p["w"])
        elif site.batch_dims:
            def apply_fn(p, x, ctx, _n=name, _bd=site.batch_dims):
                return ctx.linear(_n, x, p["w"], batch_dims=_bd)
        else:
            def apply_fn(p, x, ctx, _n=name):
                return ctx.linear(_n, x, p["w"])

        sub = BlockHandle(name=f"{block.name}/{name}", params={"w": w},
                          apply=apply_fn,
                          sites={name: Site(path=("w",), kind=site.kind,
                                            batch_dims=site.batch_dims)})
        yield name, site, sub, x_site


def quantize_blocks(blocks: List[BlockHandle], recipe: QuantRecipe,
                    x0: jax.Array, key: Optional[jax.Array] = None,
                    as_qtensor: bool = True,
                    checkpoint_dir: Optional[str] = None,
                    progress: Optional[Callable[[str], None]] = None,
                    ) -> Tuple[List[Any], Dict[str, Any], List[BlockReport]]:
    """Sequentially quantize a chain of blocks (the paper's full procedure).

    Returns (per-block finalized params, astates, reports). If
    ``checkpoint_dir`` is set, per-block state is saved after each block and
    a crashed run resumes at the first un-finalized block.
    """
    key = key if key is not None else jax.random.key(recipe.seed)
    ckpt = None
    if checkpoint_dir is not None:
        from repro.checkpoint.checkpoint import PTQCheckpointer
        ckpt = PTQCheckpointer(checkpoint_dir)

    x_fp = x0
    x_q = x0
    astates: Dict[str, Any] = {}
    finalized: List[Any] = []
    reports: List[BlockReport] = []

    start = 0
    if ckpt is not None:
        resumed = ckpt.load(blocks, recipe)
        if resumed is not None:
            start, finalized, astates, reports, x_fp, x_q = resumed

    for i in range(len(blocks)):
        block = blocks[i]
        teacher = _teacher_fn(block)
        y_fp = teacher(block.params, x_fp)
        if i < start:
            # replay streams from checkpointed finalized params
            x_q = _student_fn(block, recipe)(finalized[i], x_q, astates)
            x_fp = y_fp
            continue
        key, bkey = jax.random.split(key)
        astates = init_astates(block, recipe, x_q, prev=astates)

        if recipe.recon == "layer":
            wstates_all: Dict[str, Any] = {}
            params_cur = block.params
            cur = BlockHandle(block.name, params_cur, block.apply, block.sites)
            for name, site, sub, x_site in _explode_layerwise(cur, recipe, x_q):
                y_site = _teacher_fn(sub)(sub.params, x_site)
                ws, a_sub, rep = reconstruct_block(sub, recipe, x_site, y_site,
                                                   bkey, astates=dict(astates))
                astates.update(a_sub)
                wstates_all[name] = ws[name]
                reports.append(rep)
                params_cur = pth.set_path(
                    params_cur, site.path,
                    pth.get_path(finalize_block(sub, recipe, ws,
                                                as_qtensor=False), ("w",)))
                cur = BlockHandle(block.name, params_cur, block.apply, block.sites)
            wstates = wstates_all
        else:
            wstates, astates, rep = reconstruct_block(block, recipe, x_q, y_fp,
                                                      bkey, astates=astates)
            reports.append(rep)

        new_params = finalize_block(block, recipe, wstates, as_qtensor=as_qtensor)
        finalized.append(new_params)
        x_q = _student_fn(block, recipe)(new_params, x_q, astates)
        x_fp = y_fp
        if progress:
            progress(f"[{i + 1}/{len(blocks)}] {block.name} "
                     f"err {reports[-1].err_before:.3e} -> {reports[-1].err_after:.3e}")
        if ckpt is not None:
            ckpt.save(i + 1, finalized, astates, reports, x_fp, x_q)

    return finalized, astates, reports
