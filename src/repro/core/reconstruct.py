"""Block/layer-wise PTQ reconstruction engine (paper §3.1, §4).

Implements the sequential reconstruction the paper uses everywhere:

  for each block B (transformer layer, or single linear for layer-wise):
      y_fp = B_fp(x_fp)                      # teacher on the fp stream
      learn rounding states minimizing ||y_fp - B_q(x_q)||^2 (+AdaRound reg)
      finalize B -> integer weights; advance both streams

``x_fp`` is the full-precision activation stream; ``x_q`` the stream produced
by already-quantized predecessors (the X̃ of Eq. ||WX - Ŵ X̃||). Activation
quantizers (LSQ) are initialized from the student stream and co-trained with
the rounding states (paper: LSQ technique for the activation step size).

Distribution: all jitted functions here are pjit-compatible — calibration
tensors carry a leading sample axis that the caller shards over the data mesh
axis; gradients reduce via the standard pjit psum. Per-block state is
checkpointed (see repro/checkpoint) so a failed node restarts at the block
boundary; see quantize_blocks(resume_dir=...).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lsq
from repro.core import paths as pth
from repro.core.context import QuantCtx
from repro.core.quant_config import QuantRecipe, SitePlan
from repro.optim.adam import AdamConfig, adam_init, adam_update


@dataclasses.dataclass
class Site:
    """One quantizable weight inside a block."""
    path: Tuple  # path of the leaf within the block's param subtree
    kind: str = "linear"  # linear | conv
    batch_dims: int = 0


@dataclasses.dataclass
class BlockHandle:
    """A reconstruction unit: params + apply(params, x, ctx) -> y."""
    name: str
    params: Any
    apply: Callable[[Any, jax.Array, QuantCtx], jax.Array]
    sites: Dict[str, Site]


@dataclasses.dataclass
class BlockReport:
    name: str
    err_before: float
    err_after: float
    iters: int
    seconds: float


def site_plans(block: BlockHandle, recipe: QuantRecipe) -> Dict[str, SitePlan]:
    """Resolve the recipe's rules once per block: site name -> SitePlan."""
    return {name: recipe.resolve(name, site)
            for name, site in block.sites.items()}


def init_wstates(block: BlockHandle, recipe: QuantRecipe) -> Dict[str, Any]:
    out = {}
    for name, site in block.sites.items():
        plan = recipe.resolve(name, site)
        w = pth.get_path(block.params, site.path)
        out[name] = plan.method.init(w, plan.weight)
    return out


def init_astates(block: BlockHandle, recipe: QuantRecipe, x_q: jax.Array,
                 prev: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """LSQ init from observed ranges on the student stream (eager pass).

    Per-site rules apply here too: a site whose plan has ``act is None``
    (weight-only override) gets no LSQ state and stays fp.
    """
    if recipe.a_bits is None and not any(
            "a_bits" in dict(r.overrides) for r in recipe.rules):
        return dict(prev or {})
    ctx = QuantCtx(mode="calib", recipe=recipe)
    block.apply(block.params, x_q, ctx)
    states = dict(prev or {})
    for name, (lo, hi) in ctx.records.items():
        aq = recipe.resolve(name).act
        if aq is None:
            continue
        sample = jnp.asarray([lo, hi], jnp.float32)
        states[name] = lsq.init(sample, aq)
    return states


def _trainable_mask(wstates, astates, plans: Dict[str, SitePlan]):
    wmask = {k: plans[k].method.trainable(v) for k, v in wstates.items()}
    amask = {k: lsq.trainable(v) for k, v in astates.items()}
    return wmask, amask


def _apply_mask(grads, mask):
    return jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g), grads, mask)


def _w_opt_cfgs(plans: Dict[str, SitePlan]) -> Dict[str, AdamConfig]:
    """One AdamConfig per site so rule-overridden learning rates apply."""
    return {name: AdamConfig(lr=plan.lr) for name, plan in plans.items()}


def init_wopt(wstates: Dict[str, Any],
              w_opt_cfgs: Dict[str, AdamConfig]) -> Dict[str, Any]:
    return {k: adam_init(v, w_opt_cfgs[k]) for k, v in wstates.items()}


def make_recon_step(block: BlockHandle, recipe: QuantRecipe,
                    plans: Dict[str, SitePlan],
                    w_opt_cfgs: Dict[str, AdamConfig], a_opt_cfg: AdamConfig):
    """Builds the jitted (wstates, astates, opts, batch, step, key) -> ... fn.

    Sites may carry heterogeneous plans (method, bits, lr): each site's
    rounding state is updated by its own method + Adam config, all inside one
    jitted step.
    """

    def loss_fn(wstates, astates, x_q, y_fp, step, key):
        ctx = QuantCtx(mode="recon", recipe=recipe, wstates=wstates,
                       astates=astates, key=key)
        y = block.apply(block.params, x_q, ctx)
        mse = jnp.mean(jnp.square(y.astype(jnp.float32) - y_fp.astype(jnp.float32)))
        reg = jnp.float32(0.0)
        for name, st in wstates.items():
            plan = plans[name]
            reg = reg + plan.method.loss_extra(st, plan.weight, step, recipe)
        return mse + reg, mse

    def step_fn(wstates, astates, wopt, aopt, x_q, y_fp, step, key):
        (loss, mse), (gw, ga) = jax.value_and_grad(loss_fn, argnums=(0, 1),
                                                   has_aux=True)(
            wstates, astates, x_q, y_fp, step, key)
        wmask, amask = _trainable_mask(wstates, astates, plans)
        gw = _apply_mask(gw, wmask)
        new_w, new_wopt = {}, {}
        for k in wstates:
            st, op, _ = adam_update(gw[k], wopt[k], wstates[k], w_opt_cfgs[k])
            new_w[k] = plans[k].method.project(st)
            new_wopt[k] = op
        wstates, wopt = new_w, new_wopt
        if astates:
            ga = _apply_mask(ga, amask)
            astates, aopt, _ = adam_update(ga, aopt, astates, a_opt_cfg)
            astates = {k: lsq.project(v) for k, v in astates.items()}
        return wstates, astates, wopt, aopt, loss, mse

    # NOTE: no donation — rounding states are small, and JAX constant-dedup
    # can alias identical init buffers (e.g. zero points) across sites, which
    # makes donation reject with "same buffer twice".
    return jax.jit(step_fn)


def recon_error(block: BlockHandle, recipe: QuantRecipe, wstates, astates,
                x_q, y_fp) -> float:
    ctx = QuantCtx(mode="recon", recipe=recipe, wstates=wstates, astates=astates,
                   key=jax.random.key(recipe.seed), drop_enabled=False)
    y = block.apply(block.params, x_q, ctx)
    return float(jnp.mean(jnp.square(y.astype(jnp.float32) - y_fp.astype(jnp.float32))))


def reconstruct_block(block: BlockHandle, recipe: QuantRecipe, x_q: jax.Array,
                      y_fp: jax.Array, key: jax.Array,
                      astates: Optional[Dict[str, Any]] = None,
                      ) -> Tuple[Dict[str, Any], Dict[str, Any], BlockReport]:
    """Optimize rounding (+LSQ) states for one block. Returns final states."""
    t0 = time.time()
    plans = site_plans(block, recipe)
    wstates = init_wstates(block, recipe)
    astates = astates if astates is not None else init_astates(block, recipe, x_q)
    err0 = recon_error(block, recipe, wstates, astates, x_q, y_fp)

    w_opt_cfgs = _w_opt_cfgs(plans)
    a_opt_cfg = AdamConfig(lr=recipe.lr_lsq)
    wopt = init_wopt(wstates, w_opt_cfgs)
    aopt = adam_init(astates, a_opt_cfg)
    step_fn = make_recon_step(block, recipe, plans, w_opt_cfgs, a_opt_cfg)

    n = x_q.shape[0]
    bs = min(recipe.batch_size, n)

    @jax.jit
    def sample(key):
        return jax.random.choice(key, n, (bs,), replace=False)

    for it in range(recipe.iters):
        key, k1, k2 = jax.random.split(key, 3)
        idx = sample(k1)
        xb = jnp.take(x_q, idx, axis=0)
        yb = jnp.take(y_fp, idx, axis=0)
        wstates, astates, wopt, aopt, loss, mse = step_fn(
            wstates, astates, wopt, aopt, xb, yb, jnp.int32(it), k2)

    err1 = recon_error(block, recipe, wstates, astates, x_q, y_fp)
    rep = BlockReport(block.name, err0, err1, recipe.iters, time.time() - t0)
    return wstates, astates, rep


def finalize_block(block: BlockHandle, recipe: QuantRecipe, wstates,
                   as_qtensor: bool = True) -> Any:
    """Replace quantized leaves with QTensor (deploy) or dequant arrays.

    Each site exports with its own plan, so one block may hold QTensors of
    different bit-widths (mixed-precision recipes)."""
    from repro.core.qtensor import dequantize_qtensor
    params = block.params
    for name, site in block.sites.items():
        plan = recipe.resolve(name, site)
        w = pth.get_path(params, site.path)
        qt = plan.method.export(w, wstates[name], plan.weight, dtype=w.dtype)
        params = pth.set_path(params, site.path, qt if as_qtensor else
                              dequantize_qtensor(qt))
    return params


# --------------------------------------------------------------------- driver
def _teacher_fn(block: BlockHandle):
    return jax.jit(lambda p, x: block.apply(p, x, QuantCtx(mode="fp")))


def _student_fn(block: BlockHandle, recipe: QuantRecipe):
    def f(p, x, astates):
        ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates)
        return block.apply(p, x, ctx)
    return jax.jit(f)


def _explode_layerwise(block: BlockHandle, recipe: QuantRecipe, x_q):
    """Yield per-site sub-blocks for recon='layer' (AdaRound-style).

    Each site becomes a standalone linear/conv reconstruction problem whose
    inputs are captured from the (partially quantized) block execution.
    """
    for name, site in block.sites.items():
        ctx_q = QuantCtx(mode="capture", recipe=recipe)
        block.apply(block.params, x_q, ctx_q)
        x_site = ctx_q.records[name][0]
        w = pth.get_path(block.params, site.path)

        if site.kind == "conv":
            def apply_fn(p, x, ctx, _n=name):
                return ctx.conv2d(_n, x, p["w"])
        elif site.batch_dims:
            def apply_fn(p, x, ctx, _n=name, _bd=site.batch_dims):
                return ctx.linear(_n, x, p["w"], batch_dims=_bd)
        else:
            def apply_fn(p, x, ctx, _n=name):
                return ctx.linear(_n, x, p["w"])

        sub = BlockHandle(name=f"{block.name}/{name}", params={"w": w},
                          apply=apply_fn,
                          sites={name: Site(path=("w",), kind=site.kind,
                                            batch_dims=site.batch_dims)})
        yield name, site, sub, x_site


def quantize_blocks(blocks: List[BlockHandle], recipe: QuantRecipe,
                    x0: jax.Array, key: Optional[jax.Array] = None,
                    as_qtensor: bool = True,
                    checkpoint_dir: Optional[str] = None,
                    progress: Optional[Callable[[str], None]] = None,
                    ) -> Tuple[List[Any], Dict[str, Any], List[BlockReport]]:
    """Sequentially quantize a chain of blocks (the paper's full procedure).

    Returns (per-block finalized params, astates, reports). If
    ``checkpoint_dir`` is set, per-block state is saved after each block and
    a crashed run resumes at the first un-finalized block.
    """
    key = key if key is not None else jax.random.key(recipe.seed)
    ckpt = None
    if checkpoint_dir is not None:
        from repro.checkpoint.checkpoint import PTQCheckpointer
        ckpt = PTQCheckpointer(checkpoint_dir)

    x_fp = x0
    x_q = x0
    astates: Dict[str, Any] = {}
    finalized: List[Any] = []
    reports: List[BlockReport] = []

    start = 0
    if ckpt is not None:
        resumed = ckpt.load(blocks, recipe)
        if resumed is not None:
            start, finalized, astates, reports, x_fp, x_q = resumed

    for i in range(len(blocks)):
        block = blocks[i]
        teacher = _teacher_fn(block)
        y_fp = teacher(block.params, x_fp)
        if i < start:
            # replay streams from checkpointed finalized params
            x_q = _student_fn(block, recipe)(finalized[i], x_q, astates)
            x_fp = y_fp
            continue
        key, bkey = jax.random.split(key)
        astates = init_astates(block, recipe, x_q, prev=astates)

        if recipe.recon == "layer":
            wstates_all: Dict[str, Any] = {}
            params_cur = block.params
            cur = BlockHandle(block.name, params_cur, block.apply, block.sites)
            for name, site, sub, x_site in _explode_layerwise(cur, recipe, x_q):
                y_site = _teacher_fn(sub)(sub.params, x_site)
                ws, a_sub, rep = reconstruct_block(sub, recipe, x_site, y_site,
                                                   bkey, astates=dict(astates))
                astates.update(a_sub)
                wstates_all[name] = ws[name]
                reports.append(rep)
                params_cur = pth.set_path(
                    params_cur, site.path,
                    pth.get_path(finalize_block(sub, recipe, ws,
                                                as_qtensor=False), ("w",)))
                cur = BlockHandle(block.name, params_cur, block.apply, block.sites)
            wstates = wstates_all
        else:
            wstates, astates, rep = reconstruct_block(block, recipe, x_q, y_fp,
                                                      bkey, astates=astates)
            reports.append(rep)

        new_params = finalize_block(block, recipe, wstates, as_qtensor=as_qtensor)
        finalized.append(new_params)
        x_q = _student_fn(block, recipe)(new_params, x_q, astates)
        x_fp = y_fp
        if progress:
            progress(f"[{i + 1}/{len(blocks)}] {block.name} "
                     f"err {reports[-1].err_before:.3e} -> {reports[-1].err_after:.3e}")
        if ckpt is not None:
            plan_meta = [{n: p.summary()
                          for n, p in site_plans(b, recipe).items()}
                         for b in blocks[:i + 1]]
            ckpt.save(i + 1, finalized, astates, reports, x_fp, x_q,
                      plans=plan_meta)

    return finalized, astates, reports
