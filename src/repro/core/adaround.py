"""AdaRound baseline (Nagel et al., 2020): additive learnable rounding.

    Ŵ = s1 * ( clip( floor(W / s1) + h(V) + z, qmin, qmax ) - z )
    h(V) = clip( sigmoid(V) * (ζ - γ) + γ, 0, 1 ),  ζ = 1.1, γ = -0.1

``s1`` is FIXED (AdaRound's structural limitation highlighted by the paper);
only ``V`` is learned, with the annealed rounding regularizer

    f_reg = λ Σ (1 - |2 h(V) - 1|^β),   β: 20 → 2 (cosine), after warmup.

At export, rounding is hardened: h(V) >= 0.5 rounds up.
"""
from __future__ import annotations

import sys
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import method_api, observers, qtensor
from repro.core.quant_config import QuantConfig

ZETA = 1.1
GAMMA = -0.1


def rectified_sigmoid(v: jax.Array) -> jax.Array:
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def init(w: jax.Array, qcfg: QuantConfig, key=None) -> Dict[str, jax.Array]:
    scale, zero = observers.init_scale(w, qcfg)
    w32 = w.astype(jnp.float32)
    frac = w32 / scale - jnp.floor(w32 / scale)
    # inverse rectified sigmoid so that h(V) == frac at init (soft-exact start)
    p = jnp.clip((frac - GAMMA) / (ZETA - GAMMA), 1e-4, 1 - 1e-4)
    v = jnp.log(p / (1 - p))
    return {"s1": scale.astype(jnp.float32), "zero": zero.astype(jnp.float32), "v": v}


def _codes(w, state, qcfg, hard: bool):
    w32 = w.astype(jnp.float32)
    h = rectified_sigmoid(state["v"])
    if hard:
        h = (h >= 0.5).astype(jnp.float32)
    q = jnp.floor(w32 / state["s1"]) + h + state["zero"]
    return jnp.clip(q, qcfg.qmin, qcfg.qmax)


def codes(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig,
          ste: bool = True) -> jax.Array:
    """Hardened integer codes (h(V) >= 0.5 rounds up), matching the protocol
    contract; ``ste`` routes gradients through the soft relaxation."""
    hard = _codes(w, state, qcfg, hard=True)
    if ste:
        soft = _codes(w, state, qcfg, hard=False)
        return soft + jax.lax.stop_gradient(hard - soft)
    return hard


def apply(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig) -> jax.Array:
    q = _codes(w, state, qcfg, hard=False)
    return (state["s1"] * (q - state["zero"])).astype(w.dtype)


def loss_extra(state, qcfg, step, recipe) -> jax.Array:
    """Annealed rounding regularizer pushing h(V) to {0, 1}."""
    total = jnp.float32(recipe.iters)
    warm = total * recipe.ada_warmup
    t = jnp.clip((jnp.float32(step) - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    beta = recipe.ada_beta_end + 0.5 * (recipe.ada_beta_start - recipe.ada_beta_end) * (
        1.0 + jnp.cos(t * jnp.pi)
    )
    h = rectified_sigmoid(state["v"])
    reg = jnp.sum(1.0 - jnp.abs(2.0 * h - 1.0) ** beta)
    return jnp.where(jnp.float32(step) < warm, 0.0, recipe.ada_lambda * reg)


def trainable(state: Dict[str, jax.Array]) -> Dict[str, bool]:
    return {k: (k == "v") for k in state}


def project(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    return state


def export(w, state, qcfg: QuantConfig, dtype=jnp.bfloat16) -> qtensor.QTensor:
    q = _codes(w, state, qcfg, hard=True)
    return qtensor.from_codes(q, state["s1"], state["zero"], qcfg, dtype=dtype)


method_api.register_method("adaround")(sys.modules[__name__])
