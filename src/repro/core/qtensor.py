"""QTensor: the serialized form of a quantized weight.

Stores integer codes plus the affine grid; this is what checkpoints hold and
what the serving path consumes. Registered as a JAX pytree so it can live
inside parameter trees, be sharded by pjit, and donated.

Packing:
  - bits >= 5 .... int8 codes, one per element
  - bits <= 4 .... two 4-bit codes per int8 byte along the *first* axis
                   ("int4x2"); dims must be even on that axis.
Codes are stored zero-based for asymmetric quantizers (q in [0, 2^b-1]) and
two's-complement-shifted for symmetric ones (q + 2^(b-1), still unsigned).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quant_config import QuantConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    codes: jax.Array  # int8 storage (possibly nibble-packed)
    scale: jax.Array  # float32, broadcastable to logical shape
    zero: jax.Array   # float32, broadcastable to logical shape
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    bits: int = dataclasses.field(metadata=dict(static=True))
    packed: bool = dataclasses.field(metadata=dict(static=True))
    dtype: str = dataclasses.field(metadata=dict(static=True), default="bfloat16")

    @property
    def logical_shape(self) -> Tuple[int, ...]:
        return self.shape

    def nbytes_codes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n // 2 if self.packed else n


def _pack_nibbles(q: jax.Array) -> jax.Array:
    """q: uint8 codes in [0,15]; pack pairs along axis 0."""
    if q.shape[0] % 2 != 0:
        raise ValueError(f"int4 packing needs even dim0, got {q.shape}")
    lo = q[0::2]
    hi = q[1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def _unpack_nibbles(p: jax.Array) -> jax.Array:
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=1)  # (n/2, 2, ...)
    return out.reshape((p.shape[0] * 2,) + p.shape[1:])


def from_codes(q_float: jax.Array, scale: jax.Array, zero: jax.Array,
               qcfg: QuantConfig, dtype=jnp.bfloat16) -> QTensor:
    """Build a QTensor from float codes in [qmin, qmax] (observer output)."""
    q = jnp.round(q_float)
    offset = 0 if not qcfg.symmetric else -qcfg.qmin  # shift symmetric to unsigned
    qu = (q + offset).astype(jnp.uint8)
    packed = qcfg.bits <= 4 and q_float.shape[0] % 2 == 0
    codes = _pack_nibbles(qu) if packed else qu
    return QTensor(
        codes=codes,
        scale=jnp.asarray(scale, jnp.float32),
        zero=jnp.asarray(zero + offset, jnp.float32),
        shape=tuple(q_float.shape),
        bits=qcfg.bits,
        packed=packed,
        dtype=jnp.dtype(dtype).name,
    )


def dequantize_qtensor(qt: QTensor) -> jax.Array:
    q = _unpack_nibbles(qt.codes) if qt.packed else qt.codes
    w = qt.scale * (q.astype(jnp.float32) - qt.zero)
    return w.astype(jnp.dtype(qt.dtype))
