"""QTensor: the serialized form of a quantized weight.

Stores integer codes plus the affine grid; this is what checkpoints hold and
what the serving path consumes. Registered as a JAX pytree so it can live
inside parameter trees, be sharded by pjit, and donated.

Packing:
  - bits >= 5 .... int8 codes, one per element
  - bits <= 4 .... two 4-bit codes per int8 byte ("int4x2") along the
                   *contraction* axis: the first non-batch axis
                   (``pack_axis``; axis 0 for plain ``(d_in, d_out)``
                   weights, axis 1 for stacked expert weights
                   ``(E, d_in, d_out)``). The dim must be even on that axis.
Codes are stored zero-based for asymmetric quantizers (q in [0, 2^b-1]) and
two's-complement-shifted for symmetric ones (q + 2^(b-1), still unsigned).

The pack axis matches what the Pallas serving kernels consume (nibble pairs
adjacent along K), so deploy-mode matmuls read the packed bytes straight from
HBM; see ``kernels/dequant_matmul_w4``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quant_config import QuantConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    codes: jax.Array  # uint8 storage (possibly nibble-packed)
    scale: jax.Array  # float32, broadcastable to logical shape
    zero: jax.Array   # float32, broadcastable to logical shape
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    bits: int = dataclasses.field(metadata=dict(static=True))
    packed: bool = dataclasses.field(metadata=dict(static=True))
    dtype: str = dataclasses.field(metadata=dict(static=True), default="bfloat16")
    pack_axis: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def logical_shape(self) -> Tuple[int, ...]:
        return self.shape

    def nbytes_codes(self) -> int:
        # actual uint8 storage, not the static logical shape: stacked layers
        # (models/common.stack_layers) stack the codes buffer while `shape`
        # keeps the per-layer logical shape, so shape-derived byte counts
        # would undercount stacked trees by the layer count
        return int(self.codes.size)

    def unpacked_codes(self) -> jax.Array:
        """uint8 codes at the logical shape (nibbles expanded if packed)."""
        if not self.packed:
            return self.codes
        return _unpack_nibbles(self.codes, axis=self.pack_axis)

    def unpack(self) -> "QTensor":
        """Same logical tensor with one code per byte (no nibble packing)."""
        if not self.packed:
            return self
        return dataclasses.replace(self, codes=self.unpacked_codes(),
                                   packed=False)

    def pack(self, axis: int = None) -> "QTensor":
        """Nibble-pack <=4-bit codes along ``axis`` (default: current
        ``pack_axis``). No-op for >4-bit tensors or already-packed tensors on
        the same axis; raises if the axis dim is odd. Used to repack tensors
        exported unpacked (odd dims become even after padding upstream) or
        loaded from older checkpoints packed along a different axis."""
        axis = self.pack_axis if axis is None else axis
        if self.bits > 4:
            return self
        if self.packed and axis == self.pack_axis:
            return self
        q = self.unpacked_codes()
        return dataclasses.replace(self, codes=_pack_nibbles(q, axis=axis),
                                   packed=True, pack_axis=axis)


def _pack_nibbles(q: jax.Array, axis: int = 0) -> jax.Array:
    """q: uint8 codes in [0,15]; pack adjacent pairs along ``axis``."""
    if q.shape[axis] % 2 != 0:
        raise ValueError(f"int4 packing needs even dim on axis {axis}, "
                         f"got {q.shape}")
    lo = jax.lax.slice_in_dim(q, 0, None, stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(q, 1, None, stride=2, axis=axis)
    return (lo | (hi << 4)).astype(jnp.uint8)


def _unpack_nibbles(p: jax.Array, axis: int = 0) -> jax.Array:
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=axis + 1)  # (..., n/2, 2, ...)
    shape = p.shape[:axis] + (p.shape[axis] * 2,) + p.shape[axis + 1:]
    return out.reshape(shape)


def from_codes(q_float: jax.Array, scale: jax.Array, zero: jax.Array,
               qcfg: QuantConfig, dtype=jnp.bfloat16) -> QTensor:
    """Build a QTensor from float codes in [qmin, qmax] (observer output).

    <=4-bit codes nibble-pack along the first non-batch axis (the matmul
    contraction axis K), so ``qcfg.batch_dims`` leading axes (stacked expert
    weights) stay addressable per-expert.
    """
    q = jnp.round(q_float)
    offset = 0 if not qcfg.symmetric else -qcfg.qmin  # shift symmetric to unsigned
    qu = (q + offset).astype(jnp.uint8)
    pack_axis = min(qcfg.batch_dims, q_float.ndim - 1)
    packed = qcfg.bits <= 4 and q_float.shape[pack_axis] % 2 == 0
    codes = _pack_nibbles(qu, axis=pack_axis) if packed else qu
    return QTensor(
        codes=codes,
        scale=jnp.asarray(scale, jnp.float32),
        zero=jnp.asarray(zero + offset, jnp.float32),
        shape=tuple(q_float.shape),
        bits=qcfg.bits,
        packed=packed,
        dtype=jnp.dtype(dtype).name,
        pack_axis=pack_axis,
    )


def tree_weight_bytes(tree) -> int:
    """Effective serving bytes of a param tree: packed integer codes plus the
    affine grid for QTensor leaves, raw nbytes for everything else. This is
    the per-decode-step HBM weight traffic the roofline charges."""
    total = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda l: isinstance(l, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes_codes() + leaf.scale.nbytes + leaf.zero.nbytes
        else:
            total += leaf.nbytes
    return total


def dequantize_qtensor(qt: QTensor) -> jax.Array:
    q = qt.unpacked_codes()
    w = qt.scale * (q.astype(jnp.float32) - qt.zero)
    return w.astype(jnp.dtype(qt.dtype))
