"""Quantization configuration: per-quantizer, per-run, and per-site.

Three layers of description, smallest to largest:

  ``QuantConfig``   one uniform affine quantizer (bits, symmetry, granularity,
                    observer). Terminology follows the FlexRound paper
                    (ICML 2023): ``s1`` is the grid size (scalar per-tensor or
                    per-output-channel vector); asymmetric quantization adds an
                    integer zero point ``z``; ``per_channel`` means one (s1, z)
                    pair per *output* channel, i.e. the last axis of our JAX
                    weight convention ``W[d_in, d_out]``.

  ``QuantRecipe``   a full PTQ run (paper §4 setups): default method, weight /
                    activation configs, optimizer budget, QDrop setting — plus
                    an ordered tuple of ``rules`` for per-site overrides.

  ``SiteRule``      one override rule: a glob pattern over site names (e.g.
  + ``SitePlan``    ``"layers.0.*"``) and a mapping of recipe-field overrides.
                    ``recipe.resolve(site_name, site)`` folds all matching
                    rules (later rules win) into a ``SitePlan`` — the fully
                    resolved method + weight config + activation config + lr
                    for that one weight site. This is what makes
                    mixed-precision PTQ (W4 body + W8 first/last layers, or a
                    different rounding method per site) a first-class scenario.

Paper recipes expressed with these configs:
  vision W4/W3/W2 .... QuantConfig(bits=b, symmetric=True,  granularity="per_tensor")
  LM W8A8 ............ QuantConfig(bits=8, symmetric=False, granularity="per_tensor")
  LLaMA weights ...... QuantConfig(bits=8|4|3, symmetric=False, granularity="per_channel")
  LLM mixed W4/W8 .... QuantRecipe(w_bits=4, rules=("layers.0.*:w_bits=8",
                                                    "layers.11.*:w_bits=8"))

Method names are validated against the single registry in
:mod:`repro.core.method_api`; there is no hard-coded method list here.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
from typing import Any, Mapping, Optional, Tuple

from repro.core import method_api

GRANULARITIES = ("per_tensor", "per_channel")
OBSERVERS = ("minmax", "mse")
SETTINGS = ("brecq", "qdrop")  # activation handling during reconstruction
RECON_UNITS = ("layer", "block")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static description of one uniform affine quantizer."""

    bits: int = 8
    symmetric: bool = False
    granularity: str = "per_tensor"
    channel_axis: int = -1  # output-channel axis of the tensor being quantized
    observer: str = "mse"
    # Leading axes treated as independent sub-tensors (e.g. stacked MoE expert
    # weights (E, d_in, d_out) with batch_dims=1 get per-expert scales).
    batch_dims: int = 0

    def __post_init__(self):
        if self.granularity not in GRANULARITIES:
            raise ValueError(f"granularity {self.granularity!r} not in {GRANULARITIES}")
        if self.observer not in OBSERVERS:
            raise ValueError(f"observer {self.observer!r} not in {OBSERVERS}")
        if not (2 <= self.bits <= 8):
            raise ValueError(f"bits must be in [2, 8], got {self.bits}")

    @property
    def qmin(self) -> int:
        if self.symmetric:
            return -(2 ** (self.bits - 1) - 1)
        return 0

    @property
    def qmax(self) -> int:
        if self.symmetric:
            return 2 ** (self.bits - 1) - 1
        return 2**self.bits - 1

    @property
    def n_levels(self) -> int:
        return self.qmax - self.qmin + 1


# ------------------------------------------------------------ per-site rules
# Recipe fields a SiteRule may override.
RULE_KEYS = ("method", "w_bits", "w_symmetric", "w_granularity", "w_observer",
             "a_bits", "a_symmetric", "lr")

_BOOL_KEYS = ("w_symmetric", "a_symmetric")
_INT_KEYS = ("w_bits",)
_FLOAT_KEYS = ("lr",)


def _coerce(key: str, value: Any) -> Any:
    """Parse a string override value to its typed form (CLI / text rules)."""
    if not isinstance(value, str):
        return value
    v = value.strip()
    if key == "a_bits":
        return None if v.lower() in ("none", "off") else int(v)
    if key in _INT_KEYS:
        return int(v)
    if key in _FLOAT_KEYS:
        return float(v)
    if key in _BOOL_KEYS:
        if v.lower() in ("1", "true", "yes"):
            return True
        if v.lower() in ("0", "false", "no"):
            return False
        raise ValueError(f"rule override {key}={v!r} is not a boolean")
    return v


@dataclasses.dataclass(frozen=True)
class SiteRule:
    """One per-site override: glob ``pattern`` over site names + overrides.

    ``overrides`` is stored as a sorted tuple of (key, value) pairs so rules
    stay hashable (resolution results are cached on the frozen recipe).
    """

    pattern: str
    overrides: Tuple[Tuple[str, Any], ...]

    def __post_init__(self):
        bad = [k for k, _ in self.overrides if k not in RULE_KEYS]
        if bad:
            raise ValueError(f"rule {self.pattern!r} overrides unknown recipe "
                             f"fields {bad}; allowed: {RULE_KEYS}")

    @classmethod
    def make(cls, pattern: str, **overrides) -> "SiteRule":
        items = tuple(sorted((k, _coerce(k, v)) for k, v in overrides.items()))
        return cls(pattern=pattern, overrides=items)

    @classmethod
    def parse(cls, text: str) -> "SiteRule":
        """Parse ``"glob:key=value[,key=value...]"`` (the CLI ``--rule`` form),
        e.g. ``"layers.0.*:w_bits=8"`` or ``"*.experts.*:method=rtn,w_bits=8"``.
        """
        pattern, sep, body = text.partition(":")
        if not sep or not pattern or not body:
            raise ValueError(f"rule {text!r} is not of the form "
                             "'glob:key=value[,key=value...]'")
        kv = {}
        for part in body.split(","):
            k, eq, v = part.partition("=")
            if not eq:
                raise ValueError(f"rule {text!r}: override {part!r} has no '='")
            kv[k.strip()] = v
        return cls.make(pattern.strip(), **kv)

    def matches(self, site_name: str) -> bool:
        if fnmatch.fnmatchcase(site_name, self.pattern):
            return True
        # Leaf-targeting patterns must also cover sites that live at the top
        # level with no "layers.<i>." prefix (embeddings, lm_head): "*.w_up"
        # matches both "layers.3.mlp.w_up" and a bare "w_up"; "*.embed"
        # matches "embed". fnmatch alone requires the dot to be present.
        return (self.pattern.startswith("*.")
                and fnmatch.fnmatchcase(site_name, self.pattern[2:]))


def exact_site_pattern(site_name: str) -> str:
    """Glob pattern matching exactly ``site_name`` (fnmatch metacharacters
    escaped). Allocator-emitted rules use this so a site whose name happens
    to contain ``*``/``?``/``[`` cannot over-match."""
    out = site_name.replace("[", "[[]")
    return out.replace("*", "[*]").replace("?", "[?]")


@dataclasses.dataclass(frozen=True)
class SitePlan:
    """Fully resolved quantization plan for one weight site."""

    site_name: str
    method: method_api.RoundingMethod
    weight: QuantConfig          # batch_dims already patched for the site
    act: Optional[QuantConfig]   # None => activations stay fp at this site
    lr: float

    def summary(self) -> dict:
        """JSON-able description (checkpoint metadata, logs). Covers every
        rule-overridable field so the resume-mismatch guard catches any
        changed override, not just method/bits."""
        return {"method": self.method.name, "w_bits": self.weight.bits,
                "w_symmetric": self.weight.symmetric,
                "w_granularity": self.weight.granularity,
                "w_observer": self.weight.observer,
                "a_bits": self.act.bits if self.act is not None else None,
                "a_symmetric": (self.act.symmetric
                                if self.act is not None else None),
                "lr": self.lr}

    def cache_key(self) -> Tuple:
        """Hashable, site-name-independent summary of the resolved plan.

        Two sites with equal cache keys quantize identically up to their
        weight values, so the reconstruction engine may share one compiled
        step between them (QuantConfig is frozen/hashable; the method is
        identified by its registry name)."""
        return (self.method.name, self.weight, self.act, self.lr)


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """A full PTQ run description (paper section 4 experimental setups)."""

    method: str = "flexround"
    setting: str = "qdrop"
    recon: str = "block"

    w_bits: int = 8
    w_symmetric: bool = False
    w_granularity: str = "per_tensor"
    w_observer: str = "mse"

    a_bits: Optional[int] = 8  # None => weight-only quantization
    a_symmetric: bool = False

    iters: int = 500
    lr: float = 3e-3
    lr_lsq: float = 4e-5
    batch_size: int = 8
    drop_prob: float = 0.5  # QDrop: probability of *dropping* activation quant
    seed: int = 0

    # AdaRound regularizer schedule (Nagel et al. 2020 defaults)
    ada_lambda: float = 0.01
    ada_beta_start: float = 20.0
    ada_beta_end: float = 2.0
    ada_warmup: float = 0.2

    # gradient compression for cross-pod all-reduce during reconstruction
    grad_compress: bool = False

    # Ordered per-site overrides; later matches win. Entries may be SiteRule
    # objects or "glob:key=value[,...]" strings (parsed on construction).
    rules: Tuple[SiteRule, ...] = ()

    def __post_init__(self):
        if self.method not in method_api.available_methods():
            raise ValueError(f"method {self.method!r} not registered; "
                             f"have {method_api.available_methods()}")
        if self.setting not in SETTINGS:
            raise ValueError(f"setting {self.setting!r} not in {SETTINGS}")
        if self.recon not in RECON_UNITS:
            raise ValueError(f"recon {self.recon!r} not in {RECON_UNITS}")
        rules = tuple(SiteRule.parse(r) if isinstance(r, str) else r
                      for r in self.rules)
        for r in rules:
            m = dict(r.overrides).get("method")
            if m is not None and m not in method_api.available_methods():
                raise ValueError(f"rule {r.pattern!r}: method {m!r} not "
                                 f"registered; have "
                                 f"{method_api.available_methods()}")
        object.__setattr__(self, "rules", rules)

    # ------------------------------------------------------- site resolution
    def resolve(self, site_name: str, site: Any = None, *,
                batch_dims: int = 0) -> SitePlan:
        """Fold all matching rules (last match wins) into a SitePlan.

        ``site`` may be anything with a ``batch_dims`` attribute (a
        ``reconstruct.Site``); callers that only know the batch_dims int
        (QuantCtx) pass it directly.
        """
        if site is not None:
            batch_dims = getattr(site, "batch_dims", batch_dims)
        return _resolve_cached(self, site_name, batch_dims)

    def with_rules(self, *extra) -> "QuantRecipe":
        """New recipe with ``extra`` rules appended. Later rules win, so the
        appended rules override both recipe defaults and pre-existing rules —
        this is how allocator-emitted per-site rules lay on top of a user
        recipe. Accepts ``SiteRule`` objects or ``"glob:key=value"`` strings
        (validated by ``__post_init__`` as usual)."""
        return dataclasses.replace(self, rules=self.rules + tuple(extra))

    def overrides_for(self, site_name: str) -> Mapping[str, Any]:
        out: dict = {}
        for rule in self.rules:
            if rule.matches(site_name):
                out.update(rule.overrides)
        return out

    # -------------------------------------------- recipe-default quantizers
    def weight_qconfig(self) -> QuantConfig:
        """Recipe-default weight quantizer (no per-site rules applied).
        Prefer ``resolve(site_name).weight`` at call sites that know the
        site."""
        return QuantConfig(
            bits=self.w_bits,
            symmetric=self.w_symmetric,
            granularity=self.w_granularity,
            observer=self.w_observer,
        )

    def act_qconfig(self) -> Optional[QuantConfig]:
        """Recipe-default activation quantizer (see ``weight_qconfig``)."""
        if self.a_bits is None:
            return None
        return QuantConfig(
            bits=self.a_bits,
            symmetric=self.a_symmetric,
            granularity="per_tensor",
            observer="minmax",
        )


@functools.lru_cache(maxsize=8192)
def _resolve_cached(recipe: QuantRecipe, site_name: str,
                    batch_dims: int) -> SitePlan:
    o = dict(recipe.overrides_for(site_name))
    weight = QuantConfig(
        bits=o.get("w_bits", recipe.w_bits),
        symmetric=o.get("w_symmetric", recipe.w_symmetric),
        granularity=o.get("w_granularity", recipe.w_granularity),
        observer=o.get("w_observer", recipe.w_observer),
        batch_dims=batch_dims,
    )
    a_bits = o.get("a_bits", recipe.a_bits)
    act = None if a_bits is None else QuantConfig(
        bits=a_bits,
        symmetric=o.get("a_symmetric", recipe.a_symmetric),
        granularity="per_tensor",
        observer="minmax",
    )
    return SitePlan(
        site_name=site_name,
        method=method_api.get_method(o.get("method", recipe.method)),
        weight=weight,
        act=act,
        lr=o.get("lr", recipe.lr),
    )
