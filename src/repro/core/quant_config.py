"""Quantization configuration objects.

Terminology follows the FlexRound paper (ICML 2023):
  - ``s1``: quantization grid size (scalar per-tensor, or per-channel vector).
  - asymmetric quantization uses an integer zero point ``z``.
  - granularity ``per_channel`` means one (s1, z) pair per *output* channel,
    which for our JAX weight convention ``W[d_in, d_out]`` is the last axis.

Paper recipes expressed with these configs:
  vision W4/W3/W2 .... QuantConfig(bits=b, symmetric=True,  granularity="per_tensor")
  LM W8A8 ............ QuantConfig(bits=8, symmetric=False, granularity="per_tensor")
  LLaMA weights ...... QuantConfig(bits=8|4|3, symmetric=False, granularity="per_channel")
"""
from __future__ import annotations

import dataclasses
from typing import Optional

GRANULARITIES = ("per_tensor", "per_channel")
OBSERVERS = ("minmax", "mse")
METHODS = ("rtn", "adaround", "adaquant", "flexround")
SETTINGS = ("brecq", "qdrop")  # activation handling during reconstruction
RECON_UNITS = ("layer", "block")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static description of one uniform affine quantizer."""

    bits: int = 8
    symmetric: bool = False
    granularity: str = "per_tensor"
    channel_axis: int = -1  # output-channel axis of the tensor being quantized
    observer: str = "mse"
    # Leading axes treated as independent sub-tensors (e.g. stacked MoE expert
    # weights (E, d_in, d_out) with batch_dims=1 get per-expert scales).
    batch_dims: int = 0

    def __post_init__(self):
        if self.granularity not in GRANULARITIES:
            raise ValueError(f"granularity {self.granularity!r} not in {GRANULARITIES}")
        if self.observer not in OBSERVERS:
            raise ValueError(f"observer {self.observer!r} not in {OBSERVERS}")
        if not (2 <= self.bits <= 8):
            raise ValueError(f"bits must be in [2, 8], got {self.bits}")

    @property
    def qmin(self) -> int:
        if self.symmetric:
            return -(2 ** (self.bits - 1) - 1)
        return 0

    @property
    def qmax(self) -> int:
        if self.symmetric:
            return 2 ** (self.bits - 1) - 1
        return 2**self.bits - 1

    @property
    def n_levels(self) -> int:
        return self.qmax - self.qmin + 1


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """A full PTQ run description (paper section 4 experimental setups)."""

    method: str = "flexround"
    setting: str = "qdrop"
    recon: str = "block"

    w_bits: int = 8
    w_symmetric: bool = False
    w_granularity: str = "per_tensor"
    w_observer: str = "mse"

    a_bits: Optional[int] = 8  # None => weight-only quantization
    a_symmetric: bool = False

    iters: int = 500
    lr: float = 3e-3
    lr_lsq: float = 4e-5
    batch_size: int = 8
    drop_prob: float = 0.5  # QDrop: probability of *dropping* activation quant
    seed: int = 0

    # AdaRound regularizer schedule (Nagel et al. 2020 defaults)
    ada_lambda: float = 0.01
    ada_beta_start: float = 20.0
    ada_beta_end: float = 2.0
    ada_warmup: float = 0.2

    # gradient compression for cross-pod all-reduce during reconstruction
    grad_compress: bool = False

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"method {self.method!r} not in {METHODS}")
        if self.setting not in SETTINGS:
            raise ValueError(f"setting {self.setting!r} not in {SETTINGS}")
        if self.recon not in RECON_UNITS:
            raise ValueError(f"recon {self.recon!r} not in {RECON_UNITS}")

    def weight_qconfig(self) -> QuantConfig:
        return QuantConfig(
            bits=self.w_bits,
            symmetric=self.w_symmetric,
            granularity=self.w_granularity,
            observer=self.w_observer,
        )

    def act_qconfig(self) -> Optional[QuantConfig]:
        if self.a_bits is None:
            return None
        return QuantConfig(
            bits=self.a_bits,
            symmetric=self.a_symmetric,
            granularity="per_tensor",
            observer="minmax",
        )
