"""RoundingMethod protocol + the single method registry.

This module is the one place where "what is a rounding method" is defined.
A method is a bundle of pure functions over (weight, state, QuantConfig):

    init(w, qcfg, key=None) -> state            pytree of jnp arrays
    apply(w, state, qcfg) -> w_hat              differentiable fake-quant
    codes(w, state, qcfg, ste=True) -> q        float integer codes (optional)
    loss_extra(state, qcfg, step, recipe) -> r  regularizer (0 by default)
    trainable(state) -> {leaf: bool}            which state leaves get grads
    project(state) -> state                     post-step feasibility clamp
    export(w, state, qcfg, dtype=...) -> QTensor  hard integer export

Registering a method makes it available everywhere at once — ``QuantRecipe``
validation, per-site rule resolution, the reconstruction engine, and the CLI
``--method`` choices all read this registry. A third-party method needs one
``@register_method("name")`` and zero edits elsewhere:

    from repro.core.method_api import register_method

    @register_method("half-up")
    class HalfUp:
        def init(self, w, qcfg, key=None): ...
        def apply(self, w, state, qcfg): ...
        ...

Activation quantizers (LSQ) register with ``kind="activation"``; they share
the same state-machine surface minus ``codes``/``export``.

The existing free-function modules (``rtn``, ``adaround``, ``adaquant``,
``flexround``, ``lsq``) register themselves at import; ``methods.get()``
remains as a thin deprecated alias for one release.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

WEIGHT_REQUIRED = ("init", "apply", "trainable", "project", "export")
ACT_REQUIRED = ("init", "apply", "trainable", "project")
KINDS = ("weight", "activation")


def _zero_loss_extra(state, qcfg, step, recipe):
    import jax.numpy as jnp

    return jnp.float32(0.0)


@dataclasses.dataclass(frozen=True)
class RoundingMethod:
    """A registered rounding scheme (weight) or activation quantizer."""

    name: str
    kind: str
    init: Callable[..., Any]
    apply: Callable[..., Any]
    trainable: Callable[[Any], Dict[str, bool]]
    project: Callable[[Any], Any]
    loss_extra: Callable[..., Any] = _zero_loss_extra
    codes: Optional[Callable[..., Any]] = None
    export: Optional[Callable[..., Any]] = None

    def __repr__(self) -> str:
        return f"RoundingMethod({self.name!r}, kind={self.kind!r})"


_REGISTRY: Dict[str, RoundingMethod] = {}
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    """Import the built-in method modules so they self-register (lazy to
    avoid import cycles: method modules import quant_config, which imports
    this module)."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    try:
        from repro.core import adaquant, adaround, flexround, lsq, rtn  # noqa: F401
    except BaseException:
        _BUILTINS_LOADED = False  # retry next call instead of caching a
        raise                     # partial registry behind an empty error


def register_method(name: str, kind: str = "weight", override: bool = False):
    """Decorator registering a method under ``name``.

    Accepts a class (instantiated once), an instance, or a module object —
    anything whose attributes implement the protocol. Missing ``loss_extra``
    defaults to zero; ``codes`` is optional; ``export`` is required for
    weight methods (the engine hard-exports to QTensor). Re-registering an
    existing name raises unless ``override=True`` — checkpoint plans match
    methods by name, so a silent swap would corrupt resumes.
    """
    if kind not in KINDS:
        raise ValueError(f"kind {kind!r} not in {KINDS}")

    def deco(obj):
        if name in _REGISTRY and not override:
            raise ValueError(f"method {name!r} is already registered; pass "
                             "override=True to replace it")
        impl = obj() if isinstance(obj, type) else obj
        required = WEIGHT_REQUIRED if kind == "weight" else ACT_REQUIRED
        missing = [a for a in required if not callable(getattr(impl, a, None))]
        if missing:
            raise TypeError(
                f"method {name!r} is missing required callables {missing}; "
                f"the RoundingMethod protocol needs {required}")
        _REGISTRY[name] = RoundingMethod(
            name=name,
            kind=kind,
            init=impl.init,
            apply=impl.apply,
            trainable=impl.trainable,
            project=impl.project,
            loss_extra=getattr(impl, "loss_extra", None) or _zero_loss_extra,
            codes=getattr(impl, "codes", None),
            export=getattr(impl, "export", None),
        )
        return obj

    return deco


def get_method(name: str) -> RoundingMethod:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown rounding method {name!r}; "
                       f"have {sorted(_REGISTRY)}") from None


def available_methods(kind: str = "weight") -> Tuple[str, ...]:
    """Registered method names (registration order) — drives QuantRecipe
    validation and CLI choices."""
    _ensure_builtins()
    return tuple(n for n, m in _REGISTRY.items() if m.kind == kind)
