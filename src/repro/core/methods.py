"""DEPRECATED alias for :mod:`repro.core.method_api`.

Historically this module held a hand-maintained ``REGISTRY`` dict that had to
be kept in sync with the ``METHODS`` tuple in ``quant_config.py`` and the
argparse choices in ``launch/quantize.py``. All three now derive from the
single registry in ``method_api``; ``get()`` below is kept for one release so
downstream code migrates with a warning instead of a break.

    methods.get("flexround")      ->  method_api.get_method("flexround")
    methods.REGISTRY              ->  dict over method_api.available_methods()

Note both now return ``RoundingMethod`` bundles, not the raw modules: the
seven protocol callables (``init/apply/codes/loss_extra/trainable/project/
export``) are preserved, but module-private extras (``adaround.ZETA``,
``flexround.divisor``, ...) are only on the modules themselves — import
those directly.
"""
from __future__ import annotations

import warnings

from repro.core import method_api


def get(name: str) -> method_api.RoundingMethod:
    """Deprecated: use ``method_api.get_method``."""
    warnings.warn(
        "repro.core.methods.get() is deprecated; use "
        "repro.core.method_api.get_method()", DeprecationWarning, stacklevel=2)
    return method_api.get_method(name)


def __getattr__(attr: str):
    if attr == "REGISTRY":
        warnings.warn(
            "repro.core.methods.REGISTRY is deprecated; use "
            "repro.core.method_api.available_methods()/get_method()",
            DeprecationWarning, stacklevel=2)
        # the historical REGISTRY held weight-rounding entries only
        return {n: method_api.get_method(n)
                for n in method_api.available_methods()}
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")
