"""Registry of weight-rounding methods (paper §3 + baselines it compares to)."""
from __future__ import annotations

from repro.core import adaquant, adaround, flexround, rtn

REGISTRY = {
    "rtn": rtn,
    "adaround": adaround,
    "adaquant": adaquant,
    "flexround": flexround,
}


def get(name: str):
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown rounding method {name!r}; have {list(REGISTRY)}")
