"""Tiny helpers for addressing leaves in nested-dict param trees by path."""
from __future__ import annotations

from typing import Any, Tuple


def get_path(tree: Any, path: Tuple) -> Any:
    node = tree
    for p in path:
        node = node[p]
    return node


def set_path(tree: Any, path: Tuple, value: Any) -> Any:
    """Functional set: returns a new tree with tree[path] = value."""
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, dict):
        out = dict(tree)
        out[head] = set_path(tree[head], rest, value)
        return out
    if isinstance(tree, (list, tuple)):
        seq = list(tree)
        seq[head] = set_path(seq[head], rest, value)
        return type(tree)(seq) if isinstance(tree, tuple) else seq
    raise TypeError(f"cannot set path {path} in {type(tree)}")
