"""QDrop (Wei et al., 2022): randomly drop activation quantization during
reconstruction so weight quantization is learned under partially-quantized
activations. ``drop_prob`` is the probability an element keeps its FP value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qdrop(x_fp: jax.Array, x_q: jax.Array, drop_prob: float, key: jax.Array,
          enabled: bool = True) -> jax.Array:
    """Element-wise mix of fp and fake-quant activations (QDrop eq. 7)."""
    if not enabled or drop_prob <= 0.0:
        return x_q
    if drop_prob >= 1.0:
        return x_fp
    keep_fp = jax.random.bernoulli(key, p=drop_prob, shape=x_fp.shape)
    return jnp.where(keep_fp, x_fp, x_q)
