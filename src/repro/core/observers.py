"""Scale/zero-point initialization ("observers").

The paper initializes the grid size ``s1`` so that RTN starts from a good
baseline; we provide the two standard choices:

- ``minmax``: scale spans the full tensor (or channel) range.
- ``mse``:    grid-search over range-shrink factors minimizing ‖W - Ŵ‖²
              (the common AdaRound/BRECQ initialization).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quant_config import QuantConfig
from repro.core import quantizer as qz

_MSE_GRID = 80
_MSE_LO = 0.20


def _range_stats(w: jax.Array, qcfg: QuantConfig) -> Tuple[jax.Array, jax.Array]:
    axes = qz.reduce_axes(w.shape, qcfg)
    wmin = jnp.min(w, axis=axes, keepdims=True)
    wmax = jnp.max(w, axis=axes, keepdims=True)
    return wmin.astype(jnp.float32), wmax.astype(jnp.float32)


def _scale_zero_from_range(wmin, wmax, qcfg: QuantConfig):
    eps = jnp.float32(1e-8)
    if qcfg.symmetric:
        amax = jnp.maximum(jnp.abs(wmin), jnp.abs(wmax))
        scale = jnp.maximum(amax / qcfg.qmax, eps)
        zero = jnp.zeros_like(scale)
    else:
        wmin = jnp.minimum(wmin, 0.0)
        wmax = jnp.maximum(wmax, 0.0)
        scale = jnp.maximum((wmax - wmin) / (qcfg.qmax - qcfg.qmin), eps)
        zero = jnp.clip(jnp.round(-wmin / scale) + qcfg.qmin, qcfg.qmin, qcfg.qmax)
    return scale, zero


def minmax_scale(w: jax.Array, qcfg: QuantConfig):
    wmin, wmax = _range_stats(w, qcfg)
    return _scale_zero_from_range(wmin, wmax, qcfg)


def mse_scale(w: jax.Array, qcfg: QuantConfig):
    """Grid-search range shrinking: candidates p*[wmin, wmax], p in [0.2, 1]."""
    w32 = w.astype(jnp.float32)
    wmin, wmax = _range_stats(w32, qcfg)
    axes = qz.reduce_axes(w.shape, qcfg)

    def err_for(p):
        scale, zero = _scale_zero_from_range(wmin * p, wmax * p, qcfg)
        what = qz.fake_quant(w32, scale, zero, qcfg, ste=False)
        err = jnp.sum((w32 - what) ** 2, axis=axes, keepdims=True)
        return err, scale, zero

    ps = jnp.linspace(_MSE_LO, 1.0, _MSE_GRID, dtype=jnp.float32)
    errs, scales, zeros = jax.lax.map(err_for, ps)
    best = jnp.argmin(errs, axis=0, keepdims=True)
    scale = jnp.take_along_axis(scales, best, axis=0)[0]
    zero = jnp.take_along_axis(zeros, best, axis=0)[0]
    return scale, zero


def init_scale(w: jax.Array, qcfg: QuantConfig):
    """Dispatch on qcfg.observer. Returns (scale, zero) broadcastable to w."""
    if qcfg.observer == "minmax":
        return minmax_scale(w, qcfg)
    if qcfg.observer == "mse":
        return mse_scale(w, qcfg)
    raise ValueError(f"unknown observer {qcfg.observer!r}")
