"""Paper core: FlexRound + rounding baselines + PTQ reconstruction engine."""
from repro.core.quant_config import QuantConfig, QuantRecipe  # noqa: F401
from repro.core.qtensor import QTensor, dequantize_qtensor  # noqa: F401
from repro.core.context import QuantCtx  # noqa: F401
from repro.core.reconstruct import (  # noqa: F401
    BlockHandle,
    Site,
    quantize_blocks,
    reconstruct_block,
    finalize_block,
)
from repro.core import (  # noqa: F401
    adaquant,
    adaround,
    flexround,
    lsq,
    methods,
    observers,
    qdrop,
    quantizer,
    rtn,
)
