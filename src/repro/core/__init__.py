"""Paper core: FlexRound + rounding baselines + PTQ reconstruction engine."""
from repro.core.method_api import (  # noqa: F401
    RoundingMethod,
    available_methods,
    get_method,
    register_method,
)
from repro.core.quant_config import (  # noqa: F401
    QuantConfig,
    QuantRecipe,
    SitePlan,
    SiteRule,
)
from repro.core.qtensor import QTensor, dequantize_qtensor  # noqa: F401
from repro.core.context import QuantCtx  # noqa: F401
from repro.core.reconstruct import (  # noqa: F401
    BlockHandle,
    Site,
    quantize_blocks,
    reconstruct_block,
    finalize_block,
)
from repro.core import (  # noqa: F401
    adaquant,
    adaround,
    flexround,
    lsq,
    method_api,
    observers,
    qdrop,
    quantizer,
    rtn,
)
