"""FlexRound (the paper's contribution, Eq. 2).

    Ŵ = s1 * ( clip( round( W / (s1 ⊙ S2 ⊙ s3 [⊙ s4]) ) + z, qmin, qmax ) - z )

- ``s1``  grid size; scalar (per-tensor) or per-output-channel vector. Learnable.
- ``s2``  element-wise division factor, same shape as W, init 1. Learnable.
- ``s3``  per-output-channel factor, init 1. Learnable.
- ``s4``  per-input-channel factor (rank-4 convolutions only), init 1. Learnable.
- ``z``   integer zero point from the observer, fixed.

Positivity of (s1, s2, s3, s4) is enforced by projection (clamp at eps) after
each optimizer step — see ``project`` — keeping the raw parametrization so that
Proposition 3.1's gradient identity  dL/dS' = -(W / S'^2) * dL/dŴ  holds
*exactly* for the autodiff gradients (tested in tests/test_flexround.py).

Weight layout conventions (JAX):
  linear  W[d_in, d_out]             -> s3 has shape (1, d_out)
  stacked W[E, d_in, d_out] (experts)-> batch_dims=1, s3 (E, 1, d_out)
  conv    W[kh, kw, c_in, c_out]     -> s3 (1, 1, 1, c_out), s4 (1, 1, c_in, 1)
"""
from __future__ import annotations

import sys
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import method_api, observers, qtensor
from repro.core import quantizer as qz
from repro.core.quant_config import QuantConfig

EPS = 1e-6


def _s3_shape(shape, qcfg: QuantConfig):
    bd = qcfg.batch_dims
    return tuple(shape[:bd]) + (1,) * (len(shape) - bd - 1) + (shape[-1],)


def _is_conv(shape, qcfg: QuantConfig) -> bool:
    return len(shape) - qcfg.batch_dims == 4


def init(w: jax.Array, qcfg: QuantConfig, key=None) -> Dict[str, jax.Array]:
    """State such that apply(w, state) == RTN fake-quant of w."""
    scale, zero = observers.init_scale(w, qcfg)
    st = {
        "s1": scale.astype(jnp.float32),
        "zero": zero.astype(jnp.float32),
        "s2": jnp.ones(w.shape, jnp.float32),
        "s3": jnp.ones(_s3_shape(w.shape, qcfg), jnp.float32),
    }
    if _is_conv(w.shape, qcfg):
        bd = qcfg.batch_dims
        s4_shape = tuple(w.shape[:bd]) + (1, 1, w.shape[bd + 2], 1)
        st["s4"] = jnp.ones(s4_shape, jnp.float32)
    return st


def divisor(state: Dict[str, jax.Array]) -> jax.Array:
    d = state["s1"] * state["s2"] * state["s3"]
    if "s4" in state:
        d = d * state["s4"]
    return d


def codes(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig,
          ste: bool = True) -> jax.Array:
    """Float integer codes (incl. zero offset), clipped to the grid."""
    w32 = w.astype(jnp.float32)
    rnd = qz.ste_round if ste else jnp.round
    q = rnd(w32 / divisor(state)) + state["zero"]
    return jnp.clip(q, qcfg.qmin, qcfg.qmax)


def apply(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig) -> jax.Array:
    """Differentiable fake-quant Ŵ (Eq. 2)."""
    q = codes(w, state, qcfg, ste=True)
    return (state["s1"] * (q - state["zero"])).astype(w.dtype)


def loss_extra(state, qcfg, step, recipe) -> jax.Array:
    return jnp.float32(0.0)


def trainable(state: Dict[str, jax.Array]) -> Dict[str, bool]:
    return {k: (k != "zero") for k in state}


def project(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    out = dict(state)
    for k in ("s1", "s2", "s3", "s4"):
        if k in out:
            out[k] = jnp.maximum(out[k], EPS)
    return out


def export(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig,
           dtype=jnp.bfloat16) -> qtensor.QTensor:
    q = codes(w, state, qcfg, ste=False)
    return qtensor.from_codes(q, state["s1"], state["zero"], qcfg, dtype=dtype)


method_api.register_method("flexround")(sys.modules[__name__])
