"""AdaQuant baseline (Hubara et al., 2021): additive perturbation + learnable s1.

    Ŵ = s1 * ( clip( round( (W + V) / s1 ) + z, qmin, qmax ) - z )

``V`` (init 0) and ``s1`` are both learned (STE through round). The paper uses
this as the "learnable grid but additive" contrast to FlexRound.
"""
from __future__ import annotations

import sys
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import method_api, observers, qtensor
from repro.core import quantizer as qz
from repro.core.quant_config import QuantConfig

EPS = 1e-6


def init(w: jax.Array, qcfg: QuantConfig, key=None) -> Dict[str, jax.Array]:
    scale, zero = observers.init_scale(w, qcfg)
    return {
        "s1": scale.astype(jnp.float32),
        "zero": zero.astype(jnp.float32),
        "v": jnp.zeros(w.shape, jnp.float32),
    }


def _codes(w, state, qcfg, ste: bool):
    w32 = w.astype(jnp.float32)
    rnd = qz.ste_round if ste else jnp.round
    q = rnd((w32 + state["v"]) / state["s1"]) + state["zero"]
    return jnp.clip(q, qcfg.qmin, qcfg.qmax)


def codes(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig,
          ste: bool = True) -> jax.Array:
    return _codes(w, state, qcfg, ste=ste)


def apply(w: jax.Array, state: Dict[str, jax.Array], qcfg: QuantConfig) -> jax.Array:
    q = _codes(w, state, qcfg, ste=True)
    return (state["s1"] * (q - state["zero"])).astype(w.dtype)


def loss_extra(state, qcfg, step, recipe) -> jax.Array:
    return jnp.float32(0.0)


def trainable(state: Dict[str, jax.Array]) -> Dict[str, bool]:
    return {k: (k in ("v", "s1")) for k in state}


def project(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    out = dict(state)
    out["s1"] = jnp.maximum(out["s1"], EPS)
    return out


def export(w, state, qcfg: QuantConfig, dtype=jnp.bfloat16) -> qtensor.QTensor:
    q = _codes(w, state, qcfg, ste=False)
    return qtensor.from_codes(q, state["s1"], state["zero"], qcfg, dtype=dtype)


method_api.register_method("adaquant")(sys.modules[__name__])
