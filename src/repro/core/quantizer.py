"""Uniform affine quantization primitives with straight-through estimators.

Conventions
-----------
- quantize:   q = clip(round(w / s) + z, qmin, qmax)       (integer code)
- dequantize: ŵ = s * (q - z)
- ``s`` (scale / grid size) broadcasts against ``w``; per-channel scales have
  shape 1 everywhere except the channel axis.
- All quant math runs in float32 regardless of input dtype; fake-quant returns
  the input dtype.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quant_config import QuantConfig


def ste_round(x: jax.Array) -> jax.Array:
    """round-to-nearest-even with identity gradient (straight-through)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def ste_clip(x: jax.Array, lo, hi) -> jax.Array:
    """clip with identity gradient (used where the paper's STE passes through)."""
    return x + jax.lax.stop_gradient(jnp.clip(x, lo, hi) - x)


def grad_scale(x: jax.Array, g) -> jax.Array:
    """Forward identity; scales the gradient by ``g`` (LSQ trick)."""
    return x * g + jax.lax.stop_gradient(x - x * g)


def scale_shape(shape: Tuple[int, ...], qcfg: QuantConfig) -> Tuple[int, ...]:
    keep = set(range(qcfg.batch_dims))
    if qcfg.granularity == "per_channel":
        keep.add(qcfg.channel_axis % len(shape))
    return tuple(shape[i] if i in keep else 1 for i in range(len(shape)))


def reduce_axes(shape: Tuple[int, ...], qcfg: QuantConfig) -> Tuple[int, ...]:
    """Axes to reduce over when computing per-scale statistics."""
    keep = set(range(qcfg.batch_dims))
    if qcfg.granularity == "per_channel":
        keep.add(qcfg.channel_axis % len(shape))
    return tuple(i for i in range(len(shape)) if i not in keep)


def quantize(w: jax.Array, scale: jax.Array, zero: jax.Array, qcfg: QuantConfig,
             ste: bool = True) -> jax.Array:
    """Float integer codes in [qmin, qmax]; differentiable via STE if asked."""
    w32 = w.astype(jnp.float32)
    rnd = ste_round if ste else jnp.round
    q = rnd(w32 / scale) + zero
    return jnp.clip(q, qcfg.qmin, qcfg.qmax)


def dequantize(q: jax.Array, scale: jax.Array, zero: jax.Array) -> jax.Array:
    return scale * (q.astype(jnp.float32) - zero)


def fake_quant(w: jax.Array, scale: jax.Array, zero: jax.Array,
               qcfg: QuantConfig, ste: bool = True) -> jax.Array:
    q = quantize(w, scale, zero, qcfg, ste=ste)
    return dequantize(q, scale, zero).astype(w.dtype)
