"""quantcheck layer 1: interval abstract interpretation over traced jaxprs.

Runs every :class:`~repro.analysis.trace.TracedEntry` through a sound
interval interpreter and proves (or refutes) three numerics properties over
the entry's *shape envelope* (``repro.kernels.envelope``), not just the
smoke shapes it was traced at:

  QL301 int-overflow       an integer equation's value interval leaves its
                           dtype range — e.g. an int8 x int8 matmul
                           accumulating in int16. Contractions and K-axis
                           reductions are scaled up to the envelope's
                           ``k_max`` so the proof covers every serving
                           shape, and a fitting accumulator is reported as
                           an explicit proof (info).
  QL302 grid-saturation    a clamp bound is *provably always* active: the
                           clamped operand's interval lies entirely beyond
                           one bound, so the quantization grid collapses to
                           a constant. Straddling intervals (ordinary
                           clipping) never fire.
  QL303 scale-underflow    a division's divisor interval is entirely
                           subnormal (|d| < float32 tiny) — FlexRound's
                           s1*s2*s3 product down here flushes to zero on
                           TPU and kills every gradient through the
                           reciprocal rule.

Soundness over silence: invars are seeded from the entry's declared value
ranges (``TracedEntry.ranges``), from integer dtype bounds, and from const
values; everything else is TOP and marked *unknown*. The three rules only
fire on intervals whose every input was known — an unimplemented primitive
or a widened loop carry can never produce a false positive, only a missed
proof.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.analysis.report import Report
from repro.analysis.trace import TracedEntry
from repro.kernels.envelope import F32_TINY, ShapeEnvelope, get_envelope

NEG_INF = float("-inf")
POS_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Interval:
    """A closed real interval [lo, hi] with a knownness bit.

    ``known=False`` marks fallback bounds (unimplemented primitive, widened
    loop carry, unseeded float input); the QL30x rules never fire on them.
    """
    lo: float
    hi: float
    known: bool = True

    def __post_init__(self):
        assert self.lo <= self.hi, (self.lo, self.hi)

    @property
    def abs_max(self) -> float:
        return max(abs(self.lo), abs(self.hi))

    @property
    def finite(self) -> bool:
        return math.isfinite(self.lo) and math.isfinite(self.hi)

    def hull(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi),
                        self.known and other.known)

    def clip_to(self, lo: float, hi: float) -> "Interval":
        nlo = min(max(self.lo, lo), hi)
        nhi = max(min(self.hi, hi), lo)
        return Interval(nlo, nhi, self.known)


TOP = Interval(NEG_INF, POS_INF, known=False)


def _mul1(a: float, b: float) -> float:
    # IEEE inf * 0 is nan; the correct interval endpoint product is 0
    if a == 0.0 or b == 0.0:
        return 0.0
    return a * b


def _imul(a: Interval, b: Interval) -> Interval:
    ps = (_mul1(a.lo, b.lo), _mul1(a.lo, b.hi),
          _mul1(a.hi, b.lo), _mul1(a.hi, b.hi))
    return Interval(min(ps), max(ps), a.known and b.known)


def _idiv(a: Interval, b: Interval) -> Interval:
    # division where the divisor interval may include 0 is unbounded
    if b.lo <= 0.0 <= b.hi:
        return Interval(NEG_INF, POS_INF, a.known and b.known)
    inv = Interval(1.0 / b.hi, 1.0 / b.lo, b.known)
    return _imul(a, inv)


def _dtype_interval(dtype) -> Interval:
    try:
        d = np.dtype(dtype)
    except TypeError:
        return TOP   # extended dtypes (PRNG keys) carry no value range
    if d.kind == "b":
        return Interval(0.0, 1.0, known=True)
    if d.kind in "iu":
        info = np.iinfo(d)
        # dtype bounds are always true bounds, but only the narrow code
        # dtypes (int8/uint8/int16) carry *meaningful* range information —
        # full-range int32 counters/indices would turn every add into a
        # may-overflow false positive, so they stay unknown
        return Interval(float(info.min), float(info.max),
                        known=d.itemsize <= 2)
    return TOP


def _np_dtype(aval):
    """np.dtype of an aval, or None for extended dtypes (PRNG keys)."""
    if aval is None or not hasattr(aval, "dtype"):
        return None
    try:
        return np.dtype(aval.dtype)
    except TypeError:
        return None


def _const_interval(val) -> Interval:
    arr = np.asarray(val)
    if arr.size == 0:
        return Interval(0.0, 0.0)
    if arr.dtype.kind not in "biufc":
        return TOP
    if arr.dtype.kind == "c":
        return TOP
    lo = float(np.min(arr))
    hi = float(np.max(arr))
    if math.isnan(lo) or math.isnan(hi):
        return TOP
    return Interval(lo, hi)


def _round_iv(iv: Interval, fn) -> Interval:
    lo = fn(iv.lo) if math.isfinite(iv.lo) else iv.lo
    hi = fn(iv.hi) if math.isfinite(iv.hi) else iv.hi
    return Interval(float(lo), float(hi), iv.known)


# --------------------------------------------------------------- interpreter
class _Ctx:
    """Per-entry interpreter state: envelope, report sink, proof ledger."""

    def __init__(self, entry: TracedEntry, rep: Report):
        self.entry = entry
        self.rep = rep
        self.env: Optional[ShapeEnvelope] = (
            get_envelope(entry.envelope) if entry.envelope else None)
        self.proofs: List[str] = []
        self.fired: set = set()   # dedup (rule, prim, detail) per entry

    def where(self, eqn) -> str:
        return f"jaxpr:{self.entry.name}#{eqn.primitive.name}"

    def add_once(self, key, rule, name, severity, where, message):
        if key in self.fired:
            return
        self.fired.add(key)
        self.rep.add(rule, name, severity, where, message)


def _reduction_count(shape, axes, ctx: _Ctx) -> int:
    n = 1
    for ax in axes:
        n *= int(shape[ax])
    if ctx.env is not None:
        # prove over the envelope's largest contraction, not the smoke shape
        n = max(n, ctx.env.k_max)
    return max(n, 1)


def _scaled_sum(iv: Interval, n: int) -> Interval:
    return Interval(_mul1(float(n), iv.lo), _mul1(float(n), iv.hi), iv.known)


def _eval_eqn(eqn, ins: List[Interval], ctx: _Ctx) -> List[Interval]:
    p = eqn.primitive.name
    out_aval = eqn.outvars[0].aval if eqn.outvars else None

    if p in ("add", "add_any"):
        a, b = ins[:2]
        return [Interval(a.lo + b.lo, a.hi + b.hi, a.known and b.known)]
    if p == "sub":
        a, b = ins[:2]
        return [Interval(a.lo - b.hi, a.hi - b.lo, a.known and b.known)]
    if p == "mul":
        return [_imul(ins[0], ins[1])]
    if p == "div":
        return [_idiv(ins[0], ins[1])]
    if p == "neg":
        a = ins[0]
        return [Interval(-a.hi, -a.lo, a.known)]
    if p == "abs":
        a = ins[0]
        lo = 0.0 if a.lo <= 0.0 <= a.hi else min(abs(a.lo), abs(a.hi))
        return [Interval(lo, a.abs_max, a.known)]
    if p == "max":
        a, b = ins[:2]
        return [Interval(max(a.lo, b.lo), max(a.hi, b.hi),
                         a.known and b.known)]
    if p == "min":
        a, b = ins[:2]
        return [Interval(min(a.lo, b.lo), min(a.hi, b.hi),
                         a.known and b.known)]
    if p == "clamp":
        lo_b, x, hi_b = ins[:3]
        out = Interval(min(max(x.lo, lo_b.lo), hi_b.hi),
                       max(min(x.hi, hi_b.hi), lo_b.lo),
                       x.known and lo_b.known and hi_b.known)
        return [out]
    if p in ("round", "nearbyint"):
        return [_round_iv(ins[0], round)]
    if p == "floor":
        return [_round_iv(ins[0], math.floor)]
    if p == "ceil":
        return [_round_iv(ins[0], math.ceil)]
    if p == "sign":
        return [Interval(-1.0, 1.0, ins[0].known)]
    if p in ("stop_gradient", "copy", "device_put", "sharding_constraint",
             "reshape", "squeeze", "expand_dims", "broadcast_in_dim",
             "transpose", "rev", "slice", "dynamic_slice", "gather",
             "reduce_max", "reduce_min", "real", "optimization_barrier"):
        # value-preserving / value-subsetting ops (first operand carries it)
        return [ins[0] if ins else TOP] * max(len(eqn.outvars), 1)
    if p == "concatenate":
        out = ins[0]
        for iv in ins[1:]:
            out = out.hull(iv)
        return [out]
    if p == "select_n":
        out = ins[1]
        for iv in ins[2:]:
            out = out.hull(iv)
        return [out]
    if p == "pad":
        return [ins[0].hull(ins[1])]
    if p == "iota":
        size = max(int(np.prod(out_aval.shape)), 1) if out_aval else 1
        return [Interval(0.0, float(size - 1))]
    if p == "convert_element_type":
        a = ins[0]
        d = np.dtype(eqn.params["new_dtype"])
        if d.kind in "iu" and a.finite:
            # float -> int truncates toward zero; int -> int preserves
            a = _round_iv(a, math.trunc)
        return [a]
    if p == "integer_pow":
        y = int(eqn.params["y"])
        a = ins[0]
        if y == 2:
            lo = 0.0 if a.lo <= 0.0 <= a.hi else min(a.lo**2, a.hi**2)
            return [Interval(lo, a.abs_max**2, a.known)]
        return [TOP if not a.known else
                Interval(min(a.lo**y, a.hi**y), max(a.lo**y, a.hi**y),
                         a.known)] if y % 2 == 1 else [TOP]
    if p == "exp":
        a = ins[0]
        return [Interval(math.exp(min(a.lo, 700.0)) if a.finite else 0.0,
                         math.exp(min(a.hi, 700.0)) if a.finite else POS_INF,
                         a.known and a.finite)]
    if p in ("and", "or", "xor"):
        a, b = ins[:2]
        if a.lo >= 0.0 and b.lo >= 0.0 and a.finite and b.finite:
            hi = min(a.hi, b.hi) if p == "and" else a.hi + b.hi
            return [Interval(0.0, hi, a.known and b.known)]
        return [_dtype_interval(out_aval.dtype) if out_aval else TOP]
    if p in ("shift_right_logical", "shift_right_arithmetic"):
        a, s = ins[:2]
        if a.lo >= 0.0 and a.finite and s.known and s.lo >= 0.0:
            return [Interval(0.0, float(int(a.hi) >> int(s.lo)), a.known)]
        return [_dtype_interval(out_aval.dtype) if out_aval else TOP]
    if p == "shift_left":
        a, s = ins[:2]
        if a.lo >= 0.0 and a.finite and s.finite:
            return [Interval(0.0, float(int(a.hi) << int(s.hi)), a.known)]
        return [_dtype_interval(out_aval.dtype) if out_aval else TOP]
    if p == "reduce_sum":
        shape = eqn.invars[0].aval.shape
        n = _reduction_count(shape, eqn.params["axes"], ctx)
        return [_scaled_sum(ins[0], n)]
    if p == "dot_general":
        (lc, _), _ = eqn.params["dimension_numbers"]
        shape = eqn.invars[0].aval.shape
        n = _reduction_count(shape, lc, ctx)
        return [_scaled_sum(_imul(ins[0], ins[1]), n)]
    if p in ("eq", "ne", "lt", "le", "gt", "ge", "is_finite"):
        return [Interval(0.0, 1.0)]
    if p in ("psum", "pmean", "all_gather", "all_reduce"):
        # cross-device sum widens by the axis size; without a declared bound
        # treat as unknown-scaled
        return [Interval(min(iv.lo * 64, iv.lo), max(iv.hi * 64, iv.hi),
                         False) for iv in ins[:len(eqn.outvars)]]
    return []  # caller applies the dtype-range fallback per outvar


def _check_eqn(eqn, ins: List[Interval], outs: List[Interval],
               ctx: _Ctx) -> List[Interval]:
    """Run QL301/302/303 on one equation; returns ``outs`` with integer
    results clipped to their dtype range (overflow already reported)."""
    p = eqn.primitive.name

    # ---- QL303: provably subnormal divisor (FlexRound reciprocal rule)
    if p == "div" and len(ins) >= 2:
        d = ins[1]
        dt = _np_dtype(getattr(eqn.invars[1], "aval", None))
        if (d.known and dt is not None and dt.kind == "f" and d.finite
                and 0.0 < d.abs_max < F32_TINY):
            ctx.add_once(("QL303", p), "QL303", "scale-underflow", "error",
                         ctx.where(eqn),
                         f"divisor interval [{d.lo:.3g}, {d.hi:.3g}] is "
                         "entirely subnormal (< float32 tiny "
                         f"{F32_TINY:.3g}) — the scale product flushes to "
                         "zero on TPU and zeroes every gradient through "
                         "the reciprocal rule; check the EPS projection "
                         "on s1/s2/s3")

    # ---- QL302: clamp bound provably always active
    def _sat(xi: Interval, bound: Interval, side: str, kind: str):
        if not (xi.known and bound.known and xi.finite and bound.finite):
            return
        hit = (side == "low" and xi.hi < bound.lo) or \
              (side == "high" and xi.lo > bound.hi)
        if hit:
            ctx.add_once(("QL302", kind, side), "QL302", "grid-saturation",
                         "error", ctx.where(eqn),
                         f"{kind}: operand interval [{xi.lo:.4g}, "
                         f"{xi.hi:.4g}] lies entirely beyond the "
                         f"{side} clamp bound [{bound.lo:.4g}, "
                         f"{bound.hi:.4g}] — the quantization grid is "
                         "provably saturated to a constant (scale/zero "
                         "badly calibrated for the declared ranges)")

    if p == "max" and len(ins) == 2:
        a, b = ins
        # the point-interval side (literal/const bound) is the clamp bound
        if b.lo == b.hi:
            _sat(a, b, "low", "max")
        elif a.lo == a.hi:
            _sat(b, a, "low", "max")
    if p == "min" and len(ins) == 2:
        a, b = ins
        if b.lo == b.hi:
            _sat(a, b, "high", "min")
        elif a.lo == a.hi:
            _sat(b, a, "high", "min")
    if p == "clamp" and len(ins) == 3:
        _sat(ins[1], ins[0], "low", "clamp")
        _sat(ins[1], ins[2], "high", "clamp")

    # ---- QL301: integer interval leaves its dtype range
    clipped: List[Interval] = []
    for ov, iv in zip(eqn.outvars, outs):
        d = _np_dtype(getattr(ov, "aval", None))
        if d is None or d.kind not in "iu":
            clipped.append(iv)
            continue
        info = np.iinfo(d)
        if iv.known and iv.finite and (iv.lo < info.min or iv.hi > info.max):
            scaled = (" (envelope-scaled to k_max="
                      f"{ctx.env.k_max})" if ctx.env is not None
                      and p in ("dot_general", "reduce_sum") else "")
            ctx.add_once(("QL301", p, str(d)), "QL301", "int-overflow",
                         "error", ctx.where(eqn),
                         f"{p}: value interval [{iv.lo:.4g}, {iv.hi:.4g}]"
                         f"{scaled} exceeds {d.name} range "
                         f"[{info.min}, {info.max}] — integer overflow; "
                         "widen the accumulator "
                         "(preferred_element_type=jnp.int32)")
        elif (iv.known and iv.finite and p == "dot_general"
              and ctx.env is not None):
            ctx.proofs.append(
                f"{p}->{d.name}: accumulator interval [{iv.lo:.4g}, "
                f"{iv.hi:.4g}] fits for every K <= {ctx.env.k_max}")
        clipped.append(iv.clip_to(float(info.min), float(info.max)))
    return clipped


def _call_jaxpr(params: Dict[str, Any], key: str):
    j = params.get(key)
    if j is None:
        return None, ()
    if hasattr(j, "jaxpr"):   # ClosedJaxpr
        return j.jaxpr, tuple(j.consts)
    return j, ()


def _eval_jaxpr(jaxpr, in_ivals: List[Interval],
                const_ivals: List[Interval], ctx: _Ctx,
                depth: int = 0) -> List[Interval]:
    if depth > 24:
        return [TOP for _ in jaxpr.outvars]
    env: Dict[Any, Interval] = {}

    def write(var, iv: Interval):
        if type(var).__name__ == "DropVar":
            return
        env[var] = iv

    def read(var) -> Interval:
        if hasattr(var, "val"):    # Literal
            return _const_interval(var.val)
        if var in env:
            return env[var]
        aval = getattr(var, "aval", None)
        base = _dtype_interval(aval.dtype) if aval is not None and hasattr(
            aval, "dtype") else TOP
        return dataclasses.replace(base, known=False)

    for var, iv in zip(jaxpr.invars, in_ivals):
        write(var, iv)
    for var, iv in zip(jaxpr.constvars, const_ivals):
        write(var, iv)

    for eqn in jaxpr.eqns:
        ins = [read(v) for v in eqn.invars]
        p = eqn.primitive.name
        outs: List[Interval] = []

        if p in ("pjit", "closed_call", "core_call", "remat_call", "remat",
                 "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
                 "checkpoint"):
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                sub, consts = _call_jaxpr(eqn.params, key)
                if sub is not None:
                    outs = _eval_jaxpr(sub, ins, list(consts), ctx, depth + 1)
                    break
        elif p == "shard_map":
            sub, consts = _call_jaxpr(eqn.params, "jaxpr")
            if sub is not None:
                outs = _eval_jaxpr(sub, ins, list(consts), ctx, depth + 1)
                # per-shard values reassemble across devices: keep bounds
                # but drop knownness (axis sizes not modeled)
                outs = [dataclasses.replace(o, known=False) for o in outs]
        elif p in ("scan", "while"):
            sub, consts = _call_jaxpr(
                eqn.params, "jaxpr" if p == "scan" else "body_jaxpr")
            if sub is not None:
                if p == "scan":
                    nc = eqn.params.get("num_consts", 0)
                    ncar = eqn.params.get("num_carry", 0)
                    body_in = list(ins[:nc])
                    # widen carries to their dtype fallback (fixpoint-free)
                    for var in sub.invars[nc:nc + ncar]:
                        aval = getattr(var, "aval", None)
                        base = (_dtype_interval(aval.dtype)
                                if aval is not None and hasattr(aval, "dtype")
                                else TOP)
                        body_in.append(dataclasses.replace(base, known=False))
                    # xs slices keep the stacked operand's interval
                    body_in.extend(ins[nc + ncar:])
                    body_out = _eval_jaxpr(sub, body_in, list(consts), ctx,
                                           depth + 1)
                    outs = [dataclasses.replace(o, known=False)
                            for o in body_out]
                else:
                    body_in = [dataclasses.replace(
                        read(v), known=False) for v in sub.invars]
                    _eval_jaxpr(sub, body_in, list(consts), ctx, depth + 1)
                    outs = []
        else:
            outs = _eval_eqn(eqn, ins, ctx)

        if len(outs) != len(eqn.outvars):
            outs = []
            for ov in eqn.outvars:
                aval = getattr(ov, "aval", None)
                base = _dtype_interval(aval.dtype) if aval is not None and \
                    hasattr(aval, "dtype") else TOP
                outs.append(dataclasses.replace(base, known=False))

        outs = _check_eqn(eqn, ins, outs, ctx)
        for ov, iv in zip(eqn.outvars, outs):
            write(ov, iv)

    return [read(v) for v in jaxpr.outvars]


# ------------------------------------------------------------------ public
def seed_invars(entry: TracedEntry) -> List[Interval]:
    """Initial intervals for the entry's flat invars: declared range glob
    (first match wins), else integer dtype bounds, else unknown TOP."""
    out: List[Interval] = []
    for var, label in zip(entry.closed.jaxpr.invars, entry.labels):
        iv: Optional[Interval] = None
        for glob, lo, hi in entry.ranges:
            # exact match first: labels like "a_state.[0]" contain fnmatch
            # character-class metachars
            if label == glob or fnmatch.fnmatch(label, glob):
                iv = Interval(float(lo), float(hi))
                break
        if iv is None:
            aval = getattr(var, "aval", None)
            base = _dtype_interval(aval.dtype) if aval is not None and \
                hasattr(aval, "dtype") else TOP
            iv = base if base.finite else dataclasses.replace(
                base, known=False)
        out.append(iv)
    return out


def check_intervals(entry: TracedEntry) -> Report:
    """Abstract-interpret one traced entry; QL301/302/303 findings plus an
    info-level proof line when an envelope-scaled accumulator fits."""
    rep = Report()
    ctx = _Ctx(entry, rep)
    consts = [_const_interval(c) for c in entry.closed.consts]
    _eval_jaxpr(entry.closed.jaxpr, seed_invars(entry), consts, ctx)
    if ctx.proofs and not rep.errors():
        env = ctx.env
        rep.add("QL301", "int-overflow", "info",
                f"jaxpr:{entry.name}",
                f"proven: {ctx.proofs[0]}" + (
                    f" (envelope {env.layout!r})" if env else ""))
    return rep
