"""AST-level quantlint rules (QL1xx) — repo-specific static lint over src/.

These rules encode conventions the jaxpr layer cannot see (it only checks
what actually got traced):

  QL101 jit-outside-engine     ``jax.jit`` anywhere outside the engine cache.
                               Ad-hoc jits are how per-layer retraces creep
                               in — compiled callables must live behind
                               ``core.reconstruct``'s engine/LRU caches (or
                               be explicitly allowlisted with a reason).
  QL102 host-cast-in-trace     ``int()/float()/bool()`` applied to a value
                               *data-dependent on a tracer argument* inside
                               a traced scope — a concretization error at
                               best, a silent constant-fold at worst.
                               Values that merely pass through jnp on
                               concrete Python config constants
                               (``jnp.float32(cfg.eps)``) do not flag:
                               taint starts at the scope's arguments,
                               propagates through assignments/arithmetic/
                               method calls, and exits through static
                               metadata (``.shape``/``.dtype``/...).
  QL103 host-entropy-in-trace  ``time.*`` / ``np.random.*`` inside a traced
                               scope: traces once, then the "random"/"now"
                               value is baked into the compiled program.
  QL104 interpret-default-true ``interpret=True`` as a parameter default in
                               kernel code — interpret mode is a debugging
                               override, never the shipped default.
  QL105 pallas-missing-divis   a function invoking ``pl.pallas_call`` with
                               no visible grid-divisibility guard (no pad
                               helper and no ``assert ... % ...``) — Pallas
                               silently miscomputes on ragged tiles.
  QL106 adhoc-host-clock       bare ``time.time``/``time.perf_counter``/
                               ``time.monotonic`` in host code outside
                               ``repro/obs/`` and ``benchmarks/`` — ad-hoc
                               timing bypasses the telemetry layer; use
                               ``repro.obs.telemetry.Stopwatch``/``now()``
                               or a span so measurements land in the sink.
                               Clocks *inside* traced scopes are QL103's
                               domain and are not double-flagged here.

Traced scopes are detected structurally: functions decorated with
``jax.jit``/``functools.partial(jax.jit, ...)``, functions passed (by name
or inline lambda) to trace-inducing calls (``jit``, ``scan``, ``vmap``,
``grad``, ``pallas_call``, ``fori_loop``, ...), and anything nested inside
one. Methods called *from* traced code are not detected — the jaxpr layer
covers those for the entry points that matter.

Inline suppression: ``# quantlint: ignore[QL102]`` on the flagged line or
the line above (rule id optional; bare ``quantlint: ignore`` silences all).
Full lint runs audit the suppressions themselves: an ignore comment that
suppressed nothing errors as QL110 (stale-inline-ignore), mirroring the
allowlist staleness audit. Detection is tokenizer-based, so docstrings
quoting the syntax do not count as suppressions.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Set

from repro.analysis.report import Report

# Calls that trace the callable passed to them.
TRACE_INDUCERS = {
    "jit", "scan", "vmap", "pmap", "grad", "value_and_grad", "checkpoint",
    "remat", "pallas_call", "fori_loop", "while_loop", "cond", "switch",
    "shard_map", "custom_vjp", "custom_jvp", "associative_scan",
}
# Attribute roots that mark a value as tracer-producing for QL102.
_JAX_ROOTS = {"jnp", "jax", "lax", "pl"}

# Host clock chains QL106 polices outside repro/obs/ and benchmarks/
# (dotted form; QL103 owns these inside traced scopes).
_HOST_CLOCKS = {"time.time", "time.perf_counter", "time.monotonic",
                "time.process_time", "time.perf_counter_ns",
                "time.monotonic_ns", "time.time_ns"}


def _attr_chain(node: ast.AST) -> Optional[str]:
    """'jax.lax.scan' for nested Attribute/Name nodes, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    """Matches ``jax.jit`` / bare ``jit`` and ``functools.partial(jax.jit,
    ...)`` (as a call or a decorator)."""
    chain = _attr_chain(node)
    if chain in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call) and _attr_chain(node.func) in (
            "functools.partial", "partial"):
        return any(_is_jax_jit(a) for a in node.args)
    return False


def _touches_jax(node: ast.AST) -> bool:
    """True if the subtree contains an attribute chain rooted at jnp/jax."""
    for sub in ast.walk(node):
        chain = _attr_chain(sub)
        if chain and chain.split(".")[0] in _JAX_ROOTS:
            return True
    return False


# Attribute reads that leave tracer-land: static metadata, always concrete
# Python values even on a tracer.
_TAINT_EXIT_ATTRS = {"shape", "dtype", "ndim", "size", "weak_type",
                     "sharding", "itemsize", "nbytes"}


def _expr_tainted(node: ast.AST, tainted: Set[str]) -> bool:
    """Is this expression's value data-dependent on a tracer argument?

    Taint flows from names in ``tainted`` through arithmetic, subscripts,
    method calls and jnp/jax calls; it *exits* through static-metadata
    attributes (``x.shape[0]`` is a concrete int). A jnp call with no
    tainted argument (``jnp.float32(1e-6)`` on a config constant) is not
    tainted — that is the false-positive class this analysis removes.
    """
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _TAINT_EXIT_ATTRS:
            return False
        chain = _attr_chain(node)
        if chain and chain.split(".")[0] in _JAX_ROOTS:
            return False   # the module/function object itself, not data
        return _expr_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        if any(_expr_tainted(a, tainted) for a in node.args):
            return True
        if any(kw.value is not None and _expr_tainted(kw.value, tainted)
               for kw in node.keywords):
            return True
        # method call on a tainted object: x.sum(), x.astype(...)
        if isinstance(node.func, ast.Attribute):
            return _expr_tainted(node.func, tainted)
        return False
    if isinstance(node, ast.Subscript):
        return _expr_tainted(node.value, tainted)
    if isinstance(node, ast.Constant):
        return False
    return any(_expr_tainted(c, tainted)
               for c in ast.iter_child_nodes(node))


def _scope_tainted(scope: ast.AST) -> Set[str]:
    """Names data-dependent on the scope's arguments: the arguments
    themselves plus assignment targets whose RHS is tainted (iterated to a
    bounded fixpoint so chains of assignments propagate)."""
    a = scope.args
    tainted: Set[str] = {x.arg for x in a.posonlyargs + a.args + a.kwonlyargs}
    if a.vararg:
        tainted.add(a.vararg.arg)
    if a.kwarg:
        tainted.add(a.kwarg.arg)
    body = scope.body if isinstance(scope.body, list) else [scope.body]
    for _ in range(4):
        changed = False

        def mark(target):
            nonlocal changed
            for nm in ast.walk(target):
                if isinstance(nm, ast.Name) and nm.id not in tainted:
                    tainted.add(nm.id)
                    changed = True

        for stmt in body:
            for sub in ast.walk(stmt):
                if (isinstance(sub, ast.Assign)
                        and _expr_tainted(sub.value, tainted)):
                    for t in sub.targets:
                        mark(t)
                elif (isinstance(sub, (ast.AnnAssign, ast.AugAssign))
                      and sub.value is not None
                      and _expr_tainted(sub.value, tainted)):
                    mark(sub.target)
                elif (isinstance(sub, ast.For)
                      and _expr_tainted(sub.iter, tainted)):
                    mark(sub.target)
        if not changed:
            break
    return tainted


class _ScopeCollector(ast.NodeVisitor):
    """First pass: find names of functions handed to trace inducers, and
    functions whose decorators jit them."""

    def __init__(self):
        self.traced_names: Set[str] = set()
        self.decorated: Set[ast.AST] = set()
        self.inline_traced: Set[ast.AST] = set()  # lambdas / nested defs

    def visit_Call(self, node: ast.Call):
        chain = _attr_chain(node.func)
        leaf = chain.split(".")[-1] if chain else ""
        if leaf in TRACE_INDUCERS or _is_jax_jit(node.func):
            for a in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(a, ast.Name):
                    self.traced_names.add(a.id)
                elif isinstance(a, ast.Lambda):
                    self.inline_traced.add(a)
        self.generic_visit(node)

    def _check_decorators(self, node):
        for d in node.decorator_list:
            if _is_jax_jit(d):
                self.decorated.add(node)
            else:
                chain = _attr_chain(d.func if isinstance(d, ast.Call) else d)
                if chain and chain.split(".")[-1] in TRACE_INDUCERS:
                    self.decorated.add(node)
        self.generic_visit(node)

    visit_FunctionDef = _check_decorators
    visit_AsyncFunctionDef = _check_decorators


def _traced_scopes(tree: ast.Module) -> List[ast.AST]:
    """All function/lambda nodes whose bodies execute under a jax trace,
    including functions nested inside one."""
    coll = _ScopeCollector()
    coll.visit(tree)
    roots: List[ast.AST] = list(coll.decorated) + list(coll.inline_traced)
    for node in ast.walk(tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name in coll.traced_names and node not in roots):
            roots.append(node)
    # nested defs inherit tracedness from the enclosing scope
    out: List[ast.AST] = []
    seen: Set[int] = set()
    for r in roots:
        for node in ast.walk(r):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)) and id(node) not in seen):
                seen.add(id(node))
                out.append(node)
    return out


def _ignore_comments(src: str) -> dict:
    """``{lineno: comment text}`` for every *actual* ``# quantlint: ignore``
    comment, via the tokenizer — docstrings and string literals that merely
    contain the phrase (this repo documents the syntax in a few places) are
    not suppressions and must not look like stale ones."""
    import io
    import tokenize

    out = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(src).readline):
            if (tok.type == tokenize.COMMENT
                    and "quantlint: ignore" in tok.string):
                out[tok.start[0]] = tok.string
    except tokenize.TokenError:  # pragma: no cover - sources always tokenize
        pass
    return out


def _suppressed(ignores: dict, lineno: int, rule: str,
                used: Optional[Set[int]] = None) -> bool:
    """Does an ignore comment on the flagged line (or the line above) cover
    ``rule``? A hit is recorded in ``used`` so full runs can error on
    comments that suppressed nothing (QL110 stale-inline-ignore)."""
    for ln in (lineno, lineno - 1):
        text = ignores.get(ln)
        if text is not None:
            tag = text.split("quantlint: ignore", 1)[1]
            if "[" not in tag or rule in tag:
                if used is not None:
                    used.add(ln)
                return True
    return False


def lint_source(src: str, path: str = "<string>",
                report_stale_ignores: bool = False) -> Report:
    """Run every QL1xx rule over one module's source.

    ``report_stale_ignores=True`` (full runs only — partial layers would
    see false staleness) errors as QL110 on every inline
    ``# quantlint: ignore`` comment that suppressed nothing: a stale ignore
    is a standing blanket waiting to hide an unrelated future finding.
    """
    rep = Report()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:  # pragma: no cover - repo sources always parse
        rep.add("QL100", "syntax-error", "error", f"{path}:{e.lineno or 0}",
                str(e))
        return rep
    ignores = _ignore_comments(src)
    used_ignores: Set[int] = set()

    def add(rule, name, sev, lineno, msg):
        if not _suppressed(ignores, lineno, rule, used_ignores):
            rep.add(rule, name, sev, f"{path}:{lineno}", msg)

    # ---- QL101: any jax.jit call site or decorator ----------------------
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func):
            add("QL101", "jit-outside-engine", "error", node.lineno,
                "jax.jit call outside the engine cache; compiled callables "
                "belong behind core.reconstruct's engine/LRU (or allowlist "
                "with a reason)")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for d in node.decorator_list:
                if _is_jax_jit(d):
                    add("QL101", "jit-outside-engine", "error", d.lineno,
                        f"@jit decorator on {node.name!r} outside the "
                        "engine cache")

    # ---- QL104: interpret=True parameter default ------------------------
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        pos = a.posonlyargs + a.args
        defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
        pairs = list(zip(pos, defaults)) + list(zip(a.kwonlyargs, a.kw_defaults))
        for arg, default in pairs:
            if (arg.arg == "interpret"
                    and isinstance(default, ast.Constant)
                    and default.value is True):
                add("QL104", "interpret-default-true", "error", node.lineno,
                    f"{node.name!r} defaults interpret=True; interpret mode "
                    "is a debug override, resolve it via resolve_backend")

    # ---- QL105: pallas_call without a divisibility guard ----------------
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        has_pallas = False
        has_guard = False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                chain = _attr_chain(sub.func) or ""
                leaf = chain.split(".")[-1]
                if leaf == "pallas_call":
                    has_pallas = True
                if "pad" in leaf.lower():
                    has_guard = True  # pads to a block multiple
            if isinstance(sub, ast.Assert):
                for t in ast.walk(sub.test):
                    if isinstance(t, ast.BinOp) and isinstance(t.op, ast.Mod):
                        has_guard = True
        if has_pallas and not has_guard:
            add("QL105", "pallas-missing-divis", "warning", node.lineno,
                f"{node.name!r} calls pl.pallas_call with no visible "
                "grid-divisibility guard (no pad helper, no `assert ... %`)")

    # ---- QL102 / QL103: inside traced scopes ----------------------------
    scopes = _traced_scopes(tree)
    flagged: Set[tuple] = set()   # (rule, lineno): nested scopes overlap
    for scope in scopes:
        tainted = _scope_tainted(scope)
        body = scope.body if isinstance(scope.body, list) else [scope.body]
        for stmt in body:
            for sub in ast.walk(stmt):
                # skip nested function bodies: they get their own scope entry
                if sub is not stmt and isinstance(
                        sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if isinstance(sub, ast.Call):
                    chain = _attr_chain(sub.func)
                    if (chain in ("int", "float", "bool") and sub.args
                            and _expr_tainted(sub.args[0], tainted)
                            and ("QL102", sub.lineno) not in flagged):
                        flagged.add(("QL102", sub.lineno))
                        add("QL102", "host-cast-in-trace", "error",
                            sub.lineno,
                            f"{chain}() on a value data-dependent on a "
                            "tracer argument inside a traced scope — "
                            "concretizes the tracer (or bakes a constant "
                            "into the compiled program)")
                chain = _attr_chain(sub)
                if chain and (chain.startswith("time.")
                              or chain.startswith("np.random.")
                              or chain.startswith("numpy.random.")) \
                        and ("QL103", sub.lineno) not in flagged:
                    flagged.add(("QL103", sub.lineno))
                    add("QL103", "host-entropy-in-trace", "error",
                        sub.lineno,
                        f"{chain} inside a traced scope — evaluated once at "
                        "trace time, then frozen into the compiled program")

    # ---- QL106: ad-hoc host clock outside the telemetry layer -----------
    norm = path.replace(os.sep, "/")
    if "repro/obs/" not in norm and "benchmarks/" not in norm \
            and not norm.startswith("benchmarks"):
        # lines covered by a traced scope belong to QL103, not QL106
        traced_lines: Set[int] = set()
        for scope in scopes:
            end = getattr(scope, "end_lineno", None) or scope.lineno
            traced_lines.update(range(scope.lineno, end + 1))
        for node in ast.walk(tree):
            chain = _attr_chain(node)
            if (chain in _HOST_CLOCKS
                    and node.lineno not in traced_lines
                    and ("QL106", node.lineno) not in flagged):
                flagged.add(("QL106", node.lineno))
                add("QL106", "adhoc-host-clock", "error", node.lineno,
                    f"{chain} outside repro.obs — ad-hoc timing bypasses "
                    "telemetry; use repro.obs.telemetry.Stopwatch/now() or "
                    "a span so the measurement lands in the sink")

    # ---- QL110: inline ignore that suppressed nothing -------------------
    if report_stale_ignores:
        for ln in sorted(set(ignores) - used_ignores):
            rep.add("QL110", "stale-inline-ignore", "error", f"{path}:{ln}",
                    f"inline suppression {ignores[ln].strip()!r} matched no "
                    "finding — the violation it excused is gone; drop the "
                    "comment before it hides an unrelated future finding")
    return rep


def lint_file(path: str) -> Report:
    with open(path) as fh:
        src = fh.read()
    return lint_source(src, path)


def lint_tree(root: str, rel_to: Optional[str] = None,
              report_stale_ignores: bool = False) -> Report:
    """Lint every .py file under ``root``; finding paths are reported
    relative to ``rel_to`` (default: cwd) so allowlist globs like
    ``src/repro/kernels/*`` match regardless of where lint runs."""
    rep = Report()
    rel_to = rel_to or os.getcwd()
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if not d.startswith((".", "__"))]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            full = os.path.join(dirpath, fn)
            shown = os.path.relpath(full, rel_to)
            rep.extend(lint_source(open(full).read(), shown,
                                   report_stale_ignores=report_stale_ignores))
    return rep
