"""Entry-point tracers: turn the repo's real compiled callables into
:class:`TracedEntry` objects the jaxpr analyzers consume.

Each builder constructs exemplar inputs at smoke scale, obtains the
ClosedJaxpr via ``jax.jit(...).trace(...)``, and labels every flattened
invar with a human-readable path (``astates.~s0.step`` …) so findings point
at the actual pytree leaf, not "invar 17". The entries cover the
ROADMAP-level contract surfaces:

  recon_chunk      the engine's donated, scanned ``run_chunk`` (mesh on/off)
  probe            the sensitivity probe step (repro.allocate)
  qtensor_matmul   one entry per QTensor layout in the ROADMAP kernel table
  deploy_decode    the smoke LM's deploy-mode decode step (opt-in: builds
                   and quantizes a model)
  serve_prefill /  the serve engine's bucketed prefill-insert and slot
  serve_decode     decode step (opt-in; same quantized smoke LM, donated
                   slot state, int8 KV-scale range contract for QL303)

Seeded-bug variants (``drop_a_state=...``, ``per_layer=...``) deliberately
re-introduce shipped regressions so tests can assert each analyzer flags
exactly them; they are never part of the default lint run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import reconstruct as rec
from repro.core import rtn
from repro.core.quant_config import QuantConfig, QuantRecipe
from repro.core.reconstruct import (BlockHandle, Site, init_astates,
                                    init_wstates, site_plans)
from repro.obs.sink import ListSink
from repro.obs.telemetry import TELEMETRY
from repro.optim.adam import AdamConfig, adam_init


@dataclasses.dataclass(frozen=True)
class MemContract:
    """Per-entry HBM budget: ``budget(L) = fixed_bytes + per_len_bytes*L``.

    The contract memcheck (QL401) proves the entry's jaxpr peak-live bytes
    against — at the traced window length *and*, when ``envelope_len`` is
    declared, scaled up to the production window (every buffer carrying a
    ``max_len`` dim scales linearly, everything else is fixed). Liveness is
    computed on the jaxpr, i.e. *pre-fusion*: XLA fusion only shrinks real
    peaks, so ``jaxpr peak <= budget`` soundly implies the compiled program
    fits. Budgets therefore carry explicit, documented headroom over their
    semantic components (weights + window state + activation slack), and
    the rule exists to catch asymptotic regressions — a dequantized window
    materialized as persistent state, a doubled carry — not 5%% drifts.

    ``expect`` rows feed QL403 weight-traffic honesty: ``(measure,
    label_glob, expected_bytes)`` — the bytes the live accessors
    (``tree_weight_bytes``, ``serve.kv.hbm_per_slot_bytes``) report for the
    exemplar pytrees, cross-checked against the bytes the jaxpr's *live*
    invars matching ``label_glob`` actually move.
    """
    fixed_bytes: int              # window-independent budget component
    per_len_bytes: int = 0        # budget bytes per token of the window
    max_len: int = 0              # traced [*, max_len] window (0 = none)
    envelope_len: int = 0         # production window (envelope seq_max)
    slots: int = 0                # decode slots (serve entries)
    note: str = ""                # where the numbers come from
    expect: Tuple[Tuple[str, str, int], ...] = ()

    def budget_at(self, length: int) -> int:
        return self.fixed_bytes + self.per_len_bytes * int(length)


@dataclasses.dataclass
class TracedEntry:
    """One traced entry point, ready for the jaxpr analyzers."""
    name: str
    closed: Any                      # ClosedJaxpr
    labels: List[str]                # one per flat invar, in invar order
    donated: frozenset               # flat invar indices donated to XLA
    allow_unused: Tuple[str, ...] = ()   # fnmatch globs over labels
    mesh: Any = None                 # jax Mesh when the entry declares one
    dp: Tuple[str, ...] = ()         # data-parallel axis names to honor
    donated_leaves: Tuple[Any, ...] = ()  # exemplar donated arrays (alias check)
    # quantcheck (repro.analysis.intervals) inputs: value-range seeds for
    # the interval interpreter — (label glob, lo, hi), first match wins —
    # and the shape-envelope name whose k_max scales every contraction in
    # the overflow proof (kernels.envelope.SHAPE_ENVELOPES key)
    ranges: Tuple[Tuple[str, float, float], ...] = ()
    envelope: Optional[str] = None
    # memcheck (repro.analysis.memcheck) input: the entry's HBM budget
    # contract; None skips QL401 (the liveness report still runs)
    mem: Optional[MemContract] = None


def _path_str(path) -> str:
    toks = []
    for p in path:
        if hasattr(p, "key"):
            toks.append(str(p.key))
        elif hasattr(p, "idx"):
            toks.append(f"[{p.idx}]")
        else:
            toks.append(str(p).strip("."))
    return ".".join(toks)


def trace_jitted(jitted, args: Tuple, *, name: str,
                 argnames: Sequence[str],
                 donate_argnums: Tuple[int, ...] = (),
                 allow_unused: Tuple[str, ...] = (),
                 mesh=None, dp: Tuple[str, ...] = (),
                 ranges: Tuple[Tuple[str, float, float], ...] = (),
                 envelope: Optional[str] = None,
                 mem: Optional[MemContract] = None) -> TracedEntry:
    """Trace ``jitted(*args)`` and label its flattened invars.

    ``argnames`` must name each positional argument; labels come out as
    ``<argname>.<pytree path>``. ``donate_argnums`` mirrors the jit's own
    donation so the donation analyzer knows which invars XLA may reuse.
    """
    closed = jitted.trace(*args).jaxpr
    labels: List[str] = []
    donated: set = set()
    donated_leaves: List[Any] = []
    for i, (aname, arg) in enumerate(zip(argnames, args)):
        flat = jax.tree_util.tree_flatten_with_path(arg)[0]
        for path, leaf in flat:
            sub = _path_str(path)
            labels.append(f"{aname}.{sub}" if sub else aname)
            if i in donate_argnums:
                donated.add(len(labels) - 1)
                donated_leaves.append(leaf)
    n_invars = len(closed.jaxpr.invars)
    if len(labels) != n_invars:
        raise RuntimeError(
            f"{name}: invar labeling out of sync — {len(labels)} flattened "
            f"arg leaves vs {n_invars} jaxpr invars; did the jit close over "
            "an argument or take kwargs?")
    return TracedEntry(name=name, closed=closed, labels=labels,
                       donated=frozenset(donated),
                       allow_unused=tuple(allow_unused), mesh=mesh, dp=dp,
                       donated_leaves=tuple(donated_leaves),
                       ranges=tuple(ranges), envelope=envelope, mem=mem)


# ------------------------------------------------------ memory contracts
def _tree_bytes(tree) -> int:
    """Actual device bytes of a pytree's array leaves."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "dtype"))


def _window_bytes(tree, max_len: int) -> int:
    """Bytes of leaves carrying a ``max_len`` dim (the sequence window)."""
    if not max_len:
        return 0
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "shape") and max_len in leaf.shape)


def mem_contract(args, *, max_len: int = 0, envelope_len: int = 0,
                 slots: int = 0, headroom: float = 2.0,
                 len_headroom: float = 8.0, fixed_extra: int = 1 << 20,
                 note: str = "",
                 expect: Tuple[Tuple[str, str, int], ...] = ()
                 ) -> MemContract:
    """Derive an entry's :class:`MemContract` from its exemplar arguments.

    ``fixed = headroom * (non-window arg bytes) + fixed_extra`` covers the
    arguments, their (donation-aliased) outputs and smoke-scale activation
    temporaries; ``per_len = len_headroom * (window arg bytes) / max_len``
    covers the window state plus the pre-fusion f32 views the decode
    attention takes of it (jaxpr liveness counts the ``astype(f32)`` of the
    int8 codes that XLA later fuses away — see :class:`MemContract`).
    """
    total = _tree_bytes(args)
    win = _window_bytes(args, max_len)
    per_len = int(len_headroom * win / max_len) if max_len else 0
    return MemContract(
        fixed_bytes=int(headroom * (total - win)) + fixed_extra,
        per_len_bytes=per_len, max_len=max_len, envelope_len=envelope_len,
        slots=slots, note=note, expect=tuple(expect))


# --------------------------------------------------------------- toy blocks
def toy_block(key, name: str, d: int = 16, h: int = 24,
              token=None) -> BlockHandle:
    """Two-linear gelu residual block (the recon-engine test exemplar)."""
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (d, h), jnp.float32) * d**-0.5,
        "w2": jax.random.normal(k2, (h, d), jnp.float32) * h**-0.5,
    }

    def apply(p, x, ctx, _n=name):
        z = jax.nn.gelu(ctx.linear(f"{_n}.w1", x, p["w1"]))
        return ctx.linear(f"{_n}.w2", z, p["w2"]) + x

    sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
    return BlockHandle(name, params, apply, sites, apply_key=token)


def toy_chain(n: int, token: Optional[str] = "quantlint-chain",
              d: int = 16, h: int = 24) -> List[BlockHandle]:
    """``token=None`` disables engine sharing — the deliberate per-layer
    retrace used as a seeded bug."""
    keys = jax.random.split(jax.random.key(7), n)
    return [toy_block(keys[i], f"blk{i}", d, h, token=token)
            for i in range(n)]


def toy_recipe(iters: int = 6, batch_size: int = 4, w_bits: int = 4,
               a_bits: Optional[int] = 8) -> QuantRecipe:
    return QuantRecipe(method="flexround", w_bits=w_bits, a_bits=a_bits,
                       iters=iters, batch_size=batch_size)


# ------------------------------------------------------------ recon chunk
_RUN_CHUNK_ARGS = ("params", "wstates", "astates", "wopt", "aopt", "x_q",
                   "y_fp", "idx", "k2s", "steps", "salts", "sweight")


def recon_chunk_entry(mesh=None, *, n: int = 8, bs: int = 4, iters: int = 6,
                      d: int = 16, h: int = 24) -> TracedEntry:
    """The engine's ``run_chunk`` exactly as ``_run_scan`` drives it:
    donated carry states, minibatch gather (``bs < n`` forces the gather so
    the mesh variant exercises the stream re-constrain path)."""
    assert bs < n, "bs < n keeps the gather (and sharding constraints) live"
    block = toy_block(jax.random.key(3), "entry", d, h,
                      token="quantlint-recon-entry")
    recipe = toy_recipe(iters=iters, batch_size=bs)
    plans = site_plans(block, recipe)
    x_q = jax.random.normal(jax.random.key(11), (n, d), jnp.float32)
    y_fp = jax.random.normal(jax.random.key(12), (n, d), jnp.float32)

    canon = rec._canon_names(block)
    wstates = init_wstates(block, recipe)
    astates = init_astates(block, recipe, x_q)
    c_w = {canon[r]: v for r, v in wstates.items()}
    c_a = {canon[r]: astates[r] for r in block.sites if r in astates}
    salts = {canon[r]: rec._salt(r) for r in block.sites}
    wopt = adam_init(c_w, rec._W_BASE_CFG)
    aopt = adam_init(c_a, AdamConfig(lr=recipe.lr_lsq))
    c_w, c_a, wopt, aopt = rec._dealias(c_w, c_a, wopt, aopt)

    idx, k2s = rec._batch_schedule(jax.random.key(0), iters, n, bs)
    steps = jnp.arange(iters, dtype=jnp.int32)

    eng = rec._build_engine(block, recipe,
                            {canon[r]: plans[r] for r in block.sites},
                            canon, mesh)
    args = (block.params, c_w, c_a, wopt, aopt, x_q, y_fp, idx, k2s, steps,
            salts, None)
    dp = ()
    if mesh is not None:
        from repro.launch.mesh import dp_axes
        dp = dp_axes(mesh)
    # trace under live telemetry: the recon loop's spans are host-side
    # only, so the jaxpr must be identical with the sink enabled — any
    # telemetry op leaking into the trace shows up to QL201/QL202
    with TELEMETRY.enabled_scope(sink=ListSink()):
        return trace_jitted(
            eng.run_chunk, args,
            name="recon_chunk" + ("_sharded" if mesh is not None else ""),
            argnames=_RUN_CHUNK_ARGS, donate_argnums=(1, 2, 3, 4),
            # FlexRound has no step-annealed rounding regularizer (that is
            # AdaRound's b-schedule), so the scanned step index is dead by
            # design under this recipe
            allow_unused=("steps",),
            mesh=mesh, dp=dp,
            # HBM contract: the donated Adam/rounding carries alias their
            # outputs in place, so the chunk's peak is args + the scanned
            # step's gradient/activation temporaries (grads mirror the
            # carries; 2x arg headroom covers them at any chunk length)
            mem=mem_contract(
                args, headroom=2.0,
                note="donated Adam/rounding carries + calibration streams; "
                     "grads mirror the carries (2x) + 1 MiB step slack"))


# ----------------------------------------------------------------- probe
def probe_entry(bits: int = 4, d: int = 16, h: int = 24) -> TracedEntry:
    """The sensitivity probe step (repro.allocate): traced one-hot gates
    select the quantized site, so every leaf — including every gate — must
    stay live in the jaxpr."""
    from repro.allocate import sensitivity as sens
    from repro.core import paths as pth

    block = toy_block(jax.random.key(5), "probe", d, h,
                      token="quantlint-probe-entry")
    recipe = toy_recipe()
    plans = site_plans(block, recipe)
    canon = rec._canon_names(block)
    cfgs_c = {canon[rn]: dataclasses.replace(plans[rn].weight, bits=bits)
              for rn in block.sites}
    probe_fn = sens._build_probe(block, cfgs_c, canon)

    wstates = {}
    for rn, site in block.sites.items():
        w = pth.get_path(block.params, site.path)
        wstates[canon[rn]] = rtn.init(w, cfgs_c[canon[rn]])
    first = sorted(canon.values())[0]
    gates = {c: jnp.asarray(c == first) for c in canon.values()}
    x = jax.random.normal(jax.random.key(21), (4, d), jnp.float32)
    y_fp = jax.random.normal(jax.random.key(22), (4, d), jnp.float32)
    args = (block.params, x, y_fp, wstates, gates)
    return trace_jitted(probe_fn, args,
                        name="probe_step",
                        argnames=("params", "x", "y_fp", "wstates", "gates"),
                        mem=mem_contract(
                            args, headroom=2.0,
                            note="params + RTN states + probe streams; the "
                                 "gated fake-quant materializes one "
                                 "quantized weight per site (2x)"))


# --------------------------------------------------------- qtensor_matmul
def _export_qt(shape, bits, granularity="per_channel", batch_dims=0):
    qcfg = QuantConfig(bits=bits, symmetric=False, observer="minmax",
                       granularity=granularity, batch_dims=batch_dims)
    w = jax.random.normal(jax.random.key(9), shape, jnp.float32) * 0.1
    return rtn.export(w, rtn.init(w, qcfg), qcfg, dtype=jnp.float32)


def _a_state_for(x):
    from repro.core import lsq
    aq = QuantConfig(bits=8, symmetric=False, granularity="per_tensor",
                     observer="minmax")
    st = lsq.init(jnp.asarray([float(jnp.min(x)), float(jnp.max(x))]), aq)
    return lsq.deploy_astate(st, aq)


# (name, weight shape, bits, batch_dims, with_a_state) — one row per QTensor
# layout in the ROADMAP kernel table. Dims are smoke-scale; the layout (pack
# axis, batch dims, a_state presence) is what selects the kernel.
MATMUL_LAYOUTS: Tuple[Tuple[str, Tuple[int, ...], int, int, bool], ...] = (
    ("w4_packed", (64, 32), 4, 0, False),
    ("w4a8_packed", (64, 32), 4, 0, True),
    ("w8a8", (48, 24), 8, 0, True),
    ("w8_weight_only", (48, 24), 8, 0, False),
    ("w4_odd_unpacked", (33, 24), 4, 0, False),
    ("experts_batched", (4, 32, 16), 4, 1, False),
)


def matmul_example(layout: str):
    """(x, qt, a_state) exemplar inputs for one kernel-table layout."""
    for name, shape, bits, batch_dims, with_a in MATMUL_LAYOUTS:
        if name != layout:
            continue
        qt = _export_qt(shape, bits, batch_dims=batch_dims)
        if batch_dims == 1:
            E, K, _ = shape
            x = jax.random.normal(jax.random.key(13), (E, 5, K), jnp.float32)
        else:
            x = jax.random.normal(jax.random.key(13), (5, shape[0]),
                                  jnp.float32)
        return x, qt, (_a_state_for(x) if with_a else None)
    raise KeyError(layout)


def qtensor_matmul_entry(layout: str, *,
                         drop_a_state: bool = False) -> TracedEntry:
    """One kernel-table layout traced through ``kernels.ops.qtensor_matmul``
    on the XLA ref path.

    ``drop_a_state=True`` re-introduces the PR 5 regression — the wrapper
    accepts the activation grid but never hands it to the kernel — so the
    unused-input analyzer has a known-bad fixture to flag.
    """
    from repro.kernels import ops as kops
    from repro.kernels.envelope import get_envelope
    x, qt, a_state = matmul_example(layout)
    env = get_envelope(layout)
    # value-range contract for the interval interpreter: activation
    # magnitude and grid-scale bounds come from the layout's envelope;
    # codes/zero live on the integer grid
    ranges = (
        ("x*", -env.x_abs_max, env.x_abs_max),
        ("qt.scale*", env.scale_min, env.scale_max),
        ("qt.zero*", 0.0, float(env.code_max)),
        ("a_state.[0]", env.scale_min, env.scale_max),    # deploy a_scale
        ("a_state.[1]", 0.0, 255.0),                      # deploy a_zero
    )

    def run(x, qt, a_state):
        passed = None if drop_a_state else a_state
        return kops.qtensor_matmul(x, qt, a_state=passed, backend="xla")

    args: Tuple[Any, ...] = (x, qt)
    argnames: Tuple[str, ...] = ("x", "qt")
    fn: Callable = lambda x, qt: run(x, qt, None)  # noqa: E731
    if a_state is not None:
        args = (x, qt, a_state)
        argnames = ("x", "qt", "a_state")
        fn = run
    name = f"qtensor_matmul[{layout}]"
    if drop_a_state:
        name += "[seeded:a_state_drop]"
    return trace_jitted(jax.jit(fn), args, name=name, argnames=argnames,
                        ranges=ranges, envelope=layout)


def matmul_entries() -> List[TracedEntry]:
    return [qtensor_matmul_entry(row[0]) for row in MATMUL_LAYOUTS]


# ----------------------------------------------------------- deploy decode
def _deploy_smoke_lm(arch: str):
    """Quantize the smoke LM (iters=0: export-only) and return the deploy
    pieces — ``(cfg, model, qparams, ctx)`` — that the decode and serving
    entries trace through."""
    from repro.configs import get_smoke_config
    from repro.core.context import QuantCtx
    from repro.core.reconstruct import quantize_blocks
    from repro.data import CalibrationSet, SyntheticTokens
    from repro.models import build_model

    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    recipe = QuantRecipe(method="flexround", w_bits=4, a_bits=8, iters=0,
                         batch_size=4)
    cal = CalibrationSet.build(SyntheticTokens(vocab=cfg.vocab, seq_len=16,
                                               seed=0), 4)
    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)
    finalized, astates, _ = quantize_blocks(blocks, recipe, x0)
    qparams = assemble(finalized)
    ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates,
                   backend="xla")
    return cfg, model, qparams, ctx


def deploy_decode_entry(arch: str = "smollm-135m",
                        allow_unused: Tuple[str, ...] = (),
                        ) -> TracedEntry:
    """The smoke LM's deploy-mode decode step — every QTensor
    code/scale/zero leaf and every LSQ deploy grid must stay live through
    the serving path."""
    from repro.core.qtensor import tree_weight_bytes
    from repro.kernels.envelope import get_envelope
    from repro.serve import kv as skv

    cfg, model, qparams, ctx = _deploy_smoke_lm(arch)
    batch, prompt = 2, 8
    max_len = prompt + 4
    tokens = jax.random.randint(jax.random.key(1), (batch, prompt), 0,
                                cfg.vocab)
    cache = model.init_cache(batch, max_len)
    step = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos, ctx))
    tok = tokens[:, -1:]
    args = (qparams, tok, cache, jnp.int32(prompt))
    return trace_jitted(
        step, args,
        name=f"deploy_decode[{cfg.name}]",
        argnames=("params", "tokens", "cache", "pos"),
        allow_unused=allow_unused,
        mem=mem_contract(
            args, max_len=max_len,
            envelope_len=get_envelope("serve_kv").seq_max, slots=batch,
            note="packed weights + [batch, max_len] fp KV window; len "
                 "headroom covers the attention's f32 window views",
            expect=(("weights", "params*", tree_weight_bytes(qparams)),
                    ("kv_cache", "cache*", skv.cache_bytes(cache)))))


# ------------------------------------------------------------ serve engine
def _serve_smoke_config(*, kv_quant: bool = True, dtype=None):
    """The one smoke-scale ``EngineConfig`` every serve trace entry uses.

    ``max_len=24`` deliberately: memcheck classifies a buffer as
    window-scaled when ``max_len`` appears in its shape, so the window
    length must be unique among the smoke LM's dims (d_model=64, heads=4,
    kv_heads=2, head_dim=16, d_ff=128, vocab=128) — 16 would make every
    attention head-dim buffer look like KV state. Buckets stay [8, 16].
    """
    from repro.serve import engine as seng

    kw = {} if dtype is None else {"dtype": dtype}
    return seng.EngineConfig(slots=2, max_len=24, prefill_group=2,
                             kv_quant=kv_quant, min_bucket=8, **kw)


def _serve_kv_ranges(prefix: str) -> Tuple[Tuple[str, float, float], ...]:
    """Value-range contract for the slot state's int8 KV cache: stored
    scales are floored at kv_quantize's KV_SCALE_MIN (so QL303 can prove
    no divisor/product over them is subnormal), codes live on the int8
    grid. First match wins, so scales precede the code catch-all."""
    from repro.kernels.envelope import get_envelope
    env = get_envelope("serve_kv")
    return (
        (f"{prefix}.*scale*", env.scale_min, env.scale_max),
        (f"{prefix}.*", -float(env.code_max), float(env.code_max)),
    )


def serve_prefill_entry(arch: str = "smollm-135m",
                        bucket: int = 8) -> TracedEntry:
    """The serve engine's bucketed prefill-insert (one bucket), traced on
    the exact function ``ServeEngine`` AOT-compiles: donated slot state
    (QL203 aliasing), every KV scale live (QL201), and the int8 KV scale
    range contract (QL303)."""
    from repro.core.qtensor import tree_weight_bytes
    from repro.kernels.envelope import get_envelope
    from repro.serve import engine as seng
    from repro.serve import kv as skv

    cfg, model, qparams, ctx = _deploy_smoke_lm(arch)
    ecfg = _serve_smoke_config()
    state = seng.init_state(model, ecfg)
    G = ecfg.prefill_group
    fn = jax.jit(seng.make_prefill(model, ctx, ecfg, bucket),
                 donate_argnums=(1,))
    tokens = jax.random.randint(jax.random.key(2), (G, bucket), 0,
                                cfg.vocab, dtype=jnp.int32)
    true_len = jnp.full((G,), bucket, jnp.int32)
    slot_ids = jnp.arange(G, dtype=jnp.int32)
    max_new = jnp.full((G,), 4, jnp.int32)
    args = (qparams, state, tokens, true_len, slot_ids, max_new)
    mem = mem_contract(
        args, max_len=ecfg.max_len,
        envelope_len=get_envelope("serve_kv").seq_max, slots=ecfg.slots,
        note="weights + donated [slots, max_len] slot state; the bucket's "
             "fresh prefill cache and activations are window-independent "
             "(bucket-sized) and ride in the fixed headroom",
        expect=(("weights", "params*", tree_weight_bytes(qparams)),
                ("kv_cache", "state.cache*",
                 skv.hbm_per_slot_bytes(state["cache"], ecfg.slots)
                 * ecfg.slots)))
    # traced under live telemetry: serve.prefill spans are host-side only
    with TELEMETRY.enabled_scope(sink=ListSink()):
        return trace_jitted(
            fn, args,
            name=f"serve_prefill[{cfg.name}][b{bucket}]",
            argnames=("params", "state", "tokens", "true_len", "slot_ids",
                      "max_new"),
            donate_argnums=(1,), ranges=_serve_kv_ranges("state.cache"),
            envelope="serve_kv", mem=mem)


def serve_decode_entry(arch: str = "smollm-135m",
                       kv_quant: bool = True) -> TracedEntry:
    """The serve engine's slot decode step (donated KV-cache carry,
    active-masked position/budget update) — the loop the engine runs once
    per emitted token, so a dead scale invar or a donation alias here is a
    production serving bug.

    ``kv_quant=False`` traces the bf16-KV variant of the same step: memcheck
    compares the two entries' static per-slot window bytes to prove, from
    the jaxprs alone, that the int8 cache pins strictly less HBM per slot
    than the bf16 cache (the claim the serve bench measures live).
    """
    from repro.core.qtensor import tree_weight_bytes
    from repro.kernels.envelope import get_envelope
    from repro.serve import engine as seng
    from repro.serve import kv as skv

    cfg, model, qparams, ctx = _deploy_smoke_lm(arch)
    ecfg = _serve_smoke_config(
        kv_quant=kv_quant, dtype=None if kv_quant else jnp.bfloat16)
    state = seng.init_state(model, ecfg)
    meta = {k: state[k] for k in ("tokens", "pos", "remaining")}
    fn = jax.jit(seng.make_decode(model, ctx, ecfg), donate_argnums=(1,))
    tag = "" if kv_quant else "[bf16-kv]"
    ranges = (_serve_kv_ranges("cache") if kv_quant
              else (("cache.*", -64.0, 64.0),))
    args = (qparams, state["cache"], meta)
    mem = mem_contract(
        args, max_len=ecfg.max_len,
        envelope_len=get_envelope("serve_kv").seq_max, slots=ecfg.slots,
        note="packed weights + donated [slots, max_len] KV window; len "
             "headroom covers the attention's pre-fusion f32 window views",
        expect=(("weights", "params*", tree_weight_bytes(qparams)),
                ("kv_cache", "cache*",
                 skv.hbm_per_slot_bytes(state["cache"], ecfg.slots)
                 * ecfg.slots)))
    # traced under live telemetry: serve.decode_step spans are host-side only
    with TELEMETRY.enabled_scope(sink=ListSink()):
        return trace_jitted(
            fn, args,
            name=f"serve_decode[{cfg.name}]{tag}",
            argnames=("params", "cache", "meta"),
            donate_argnums=(1,), ranges=ranges,
            envelope="serve_kv", mem=mem)


# ------------------------------------------------- quantcheck (QL3xx) entries
def flexround_apply_entry(*, underflow: bool = False,
                          d: int = 32, h: int = 16) -> TracedEntry:
    """The PTQ inner loop's fake-quant Ŵ = s1*(clip(round(W/(s1⊙S2⊙s3))+z)-z)
    traced for the interval interpreter.

    The healthy range contract mirrors ``flexround.project``: every divisor
    factor is floored at EPS = 1e-6, so the s1*s2*s3 product is provably
    normal (>= 1e-18 >> float32 tiny) and QL303 stays quiet. ``underflow=True``
    re-seeds the factors at ~1e-18 each — the projection bug quantcheck
    exists to catch — making the whole divisor interval subnormal.
    """
    from repro.core import flexround
    from repro.kernels.envelope import get_envelope

    qcfg = QuantConfig(bits=4, symmetric=False, observer="minmax",
                       granularity="per_channel")
    w = jax.random.normal(jax.random.key(17), (d, h), jnp.float32) * 0.1
    state = flexround.init(w, qcfg)
    env = get_envelope("flexround_apply")
    lo, hi = ((1e-20, 1e-18) if underflow
              else (env.scale_min, env.scale_max))
    ranges = (
        ("w*", -env.x_abs_max, env.x_abs_max),
        ("state.s1*", lo, hi),
        ("state.s2*", lo, hi),
        ("state.s3*", lo, hi),
        ("state.zero*", 0.0, float(qcfg.qmax)),
    )
    fn = jax.jit(lambda w, state: flexround.apply(w, state, qcfg))
    name = "flexround_apply"
    if underflow:
        name += "[seeded:scale_underflow]"
    return trace_jitted(fn, (w, state), name=name, argnames=("w", "state"),
                        ranges=ranges, envelope="flexround_apply")


def int8_overflow_entry() -> TracedEntry:
    """Seeded QL301 fixture: the W8A8 matmul accumulating in int16.

    int8 x int8 products reach 2^14; even the smoke-scale K = 48 contraction
    tops 2^19, and the envelope's k_max = 32768 pushes the proof bound to
    ~2^29 — either way far past int16. The healthy kernels accumulate in
    int32 (``preferred_element_type=jnp.int32``); this entry re-introduces
    the narrow accumulator so tests can pin quantcheck catching it.
    """
    a_q = jax.random.randint(jax.random.key(23), (8, 48), -128, 128,
                             dtype=jnp.int8)
    b_q = jax.random.randint(jax.random.key(24), (48, 24), -128, 128,
                             dtype=jnp.int8)

    def bad(a_q, b_q):
        acc = jax.lax.dot_general(
            a_q.astype(jnp.int16), b_q.astype(jnp.int16),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int16)
        return acc.astype(jnp.float32)

    return trace_jitted(jax.jit(bad), (a_q, b_q),
                        name="qmatmul_int8[seeded:int16_acc]",
                        argnames=("a_q", "b_q"), envelope="w8a8")


def _one_device_mesh():
    """Smallest mesh carrying both named axes — enough for shard_map
    *tracing* (the analyzers never execute the entry)."""
    import numpy as np
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def lost_psum_entry(mesh=None) -> TracedEntry:
    """Seeded QL305 fixture: a sharded loss reduction whose psum runs over
    the *model* axis instead of the data axis, with ``check_rep=False``
    silencing shard_map's own replication check — the per-host loss is
    declared replicated but never actually reduced over data parallelism,
    so every host trains on a different objective (the classic lost psum).
    """
    from jax.sharding import PartitionSpec as P

    from repro.optim.compress import get_shard_map

    mesh = mesh or _one_device_mesh()
    shard_map = get_shard_map()

    def local_loss(x, y):
        err = jnp.mean((x - y) ** 2)
        # BUG (seeded): reduces over "model", leaving "data" unreduced
        return jax.lax.psum(err, "model")

    fn = shard_map(local_loss, mesh=mesh,
                   in_specs=(P("data"), P("data")),
                   out_specs=P(), check_rep=False)
    x = jnp.ones((8, 16), jnp.float32)
    y = jnp.ones((8, 16), jnp.float32)
    return trace_jitted(jax.jit(fn), (x, y),
                        name="sharded_loss[seeded:lost_psum]",
                        argnames=("x", "y"), mesh=mesh, dp=("data",))


# ------------------------------------------------- memcheck (QL4xx) fixtures
def dead_donation_entry() -> TracedEntry:
    """Seeded QL402 fixture: an int8 codes buffer donated into a reduction
    that returns only an f32 scalar — no output shares the donated buffer's
    shape and dtype, so XLA cannot reuse the storage and the donation buys
    nothing. QL203 stays quiet (the buffer is consumed exactly once and not
    returned); this is its silent inverse, visible only to the liveness
    accounting."""
    codes = jax.random.randint(jax.random.key(31), (64, 64), -127, 128,
                               dtype=jnp.int8)

    def flush_stats(codes):
        return jnp.mean(jnp.abs(codes.astype(jnp.float32)))

    return trace_jitted(
        jax.jit(flush_stats, donate_argnums=(0,)), (codes,),
        name="kv_flush_stats[seeded:dead_donation]",
        argnames=("codes",), donate_argnums=(0,))


def hbm_blowout_entry() -> TracedEntry:
    """Seeded QL401 fixture: decode attention that dequantizes the *whole*
    int8 KV window to f32 before contracting — the regression
    ``serve.kv.int8_decode_attention`` exists to prevent. The budget is the
    honest dequant-free path's (int8 codes + f32 scales per window token,
    modest slack), so the materialized 4-bytes-per-element f32 window blows
    past it at the traced length, and 32x worse at the envelope length.
    """
    slots, max_len, heads, d = 2, 24, 2, 16
    codes = jax.random.randint(jax.random.key(32),
                               (slots, max_len, heads, d), -127, 128,
                               dtype=jnp.int8)
    scale = jnp.full((slots, max_len, heads, 1), 1e-2, jnp.float32)
    q = jax.random.normal(jax.random.key(33), (slots, 1, heads, d),
                          jnp.float32)

    def bad_attention(q, codes, scale):
        # BUG (seeded): rematerializes the full window in f32 as a named
        # intermediate (the healthy path folds scales post-contraction)
        kv = codes.astype(jnp.float32) * scale
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kv)
        return jnp.sum(jax.nn.softmax(s, axis=-1))

    args = (q, codes, scale)
    # tight, int8-sized budget: no 8x f32-view headroom, 2 KiB fixed slack
    mem = mem_contract(args, max_len=max_len, envelope_len=8192, slots=slots,
                       headroom=1.5, len_headroom=1.5, fixed_extra=2048,
                       note="dequant-free budget: int8 codes + scales only")
    return trace_jitted(
        jax.jit(bad_attention), args,
        name="decode_attention[seeded:hbm_blowout]",
        argnames=("q", "codes", "scale"), mem=mem)
