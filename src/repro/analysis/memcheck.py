"""memcheck — jaxpr-level liveness and HBM-budget verification (QL4xx).

The fourth quantlint layer. The first three prove *value* properties (AST
hygiene, dataflow wiring, interval numerics); this one proves *memory*
properties over the same :class:`~repro.analysis.trace.TracedEntry`
ClosedJaxprs, against the per-entry :class:`MemContract` budgets the trace
builders declare:

  QL401 hbm-budget          peak-live bytes exceed the entry's declared
                            budget — at the traced window length or scaled
                            to the production envelope (serve_kv seq_max).
  QL402 dead-donation       a donated buffer XLA cannot actually reuse: no
                            output shares its shape+dtype, or every
                            candidate output is defined while the donated
                            buffer is still being read. The donation buys
                            nothing — the silent inverse of QL203 (which
                            catches *unsafe* donations, not useless ones).
  QL403 weight-traffic      the bytes the jaxpr's live invars move for a
                            labeled group (packed weights, KV state)
                            drifted from what the repo's own accessors
                            (``tree_weight_bytes``, ``hbm_per_slot_bytes``)
                            — and hence the bench rows — claim.
  QL404 cache-growth (info) window state whose HBM footprint scales with
                            the *allocated* ``max_len``, not the used
                            length: the quantified paged-KV gap, reported
                            into ``--mem-json`` for the roofline's
                            peak-memory term to cross-reference.

Liveness model
--------------
One linear scan per jaxpr: a buffer materializes when its defining equation
runs (while that equation's operands are still held) and dies after its
last consuming equation, unless it is an output. Sub-jaxprs (pjit / scan /
while / cond / shard_map) are walked recursively; their inner peak minus
the bytes of the invars that alias outer operands (scan consts + carry —
the carry is thereby counted ONCE across the whole loop body, not once per
trip) is added transiently at the call equation. Donation-matched outputs
write into the donated storage and cost nothing; the donated buffer is
pinned live to the end instead.

Every buffer is classified ``(fixed, per_len)``: carrying the entry's
``max_len`` dim in its shape means its bytes scale with the sequence
window, so one smoke-scale trace yields a *length-parametric* peak —
``peak(L) = max over boundaries of (fixed + per_len * L)`` — and the same
scan proves both the traced window and the production envelope
(``ShapeEnvelope.seq_max``), the QL301 trick applied to memory.

Soundness: the jaxpr is *pre-fusion*. ``int8_decode_attention`` takes
``codes.astype(f32)`` views of the cache that XLA fuses away, so the jaxpr
peak is an upper bound on the compiled peak. Budgets carry documented
headroom for exactly those views (``trace.mem_contract``); the rule exists
to catch asymptotic regressions — a dequantized window materialized as
persistent state, a doubled carry — not 5% drift.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import math
from typing import Any, Dict, List, Sequence, Tuple

from repro.analysis.report import Report
from repro.analysis.trace import TracedEntry
from repro.roofline.analysis import UnknownDtypeError, dtype_bytes

_MIB = float(2**20)

# call-like primitives whose sub-jaxpr params key is one of the usual three
_CALL_PRIMS = ("pjit", "closed_call", "core_call", "remat_call", "remat",
               "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
               "checkpoint")


def _is_literal(v) -> bool:
    return hasattr(v, "val")


def _aval_bytes(aval) -> int:
    """Device bytes of one abstract value, via the roofline's dtype table
    (shared with the HBM-traffic model, so sub-byte packed dtypes agree).
    Extended dtypes the table doesn't know (PRNG key dtypes) fall back to
    their itemsize; a dtype with neither is the named UnknownDtypeError."""
    shape = getattr(aval, "shape", None)
    dt = getattr(aval, "dtype", None)
    if shape is None or dt is None:
        return 0  # abstract tokens and friends occupy no HBM
    n = 1
    for s in shape:
        n *= int(s)
    try:
        width = dtype_bytes(getattr(dt, "name", str(dt)))
    except UnknownDtypeError:
        itemsize = getattr(dt, "itemsize", None)
        if itemsize is None:
            raise
        width = float(itemsize)
    return math.ceil(n * width)


def _var_size(v, max_len: int) -> Tuple[int, int]:
    """(fixed, per_len) byte classification of a var: a ``max_len`` dim in
    the shape means the buffer scales with the sequence window."""
    aval = getattr(v, "aval", None)
    b = _aval_bytes(aval)
    shape = tuple(getattr(aval, "shape", ()) or ())
    if max_len and max_len in shape:
        return 0, -(-b // max_len)  # ceil(b / max_len) per window token
    return b, 0


def _sub_jaxprs(eqn) -> List[Tuple[Any, Tuple[Any, ...]]]:
    """(jaxpr, alias_invars) pairs for an equation's sub-jaxprs.

    ``alias_invars`` are the inner invars whose storage aliases an operand
    already counted live by the caller (everything for plain calls; consts
    + carry for scan/while — per-trip xs slices are genuinely new bytes).
    """

    def unwrap(j):
        return j.jaxpr if hasattr(j, "jaxpr") else j

    p = eqn.primitive.name
    out: List[Tuple[Any, Tuple[Any, ...]]] = []
    if p in _CALL_PRIMS or p == "shard_map":
        keys = ("jaxpr",) if p == "shard_map" else ("jaxpr", "call_jaxpr",
                                                    "fun_jaxpr")
        for key in keys:
            j = eqn.params.get(key)
            if j is not None:
                sub = unwrap(j)
                out.append((sub, tuple(sub.invars)))
                break
    elif p == "scan":
        sub = unwrap(eqn.params["jaxpr"])
        n_alias = eqn.params.get("num_consts", 0) + eqn.params.get(
            "num_carry", 0)
        out.append((sub, tuple(sub.invars[:n_alias])))
    elif p == "while":
        for key in ("cond_jaxpr", "body_jaxpr"):
            j = eqn.params.get(key)
            if j is not None:
                sub = unwrap(j)
                out.append((sub, tuple(sub.invars)))
    elif p == "cond":
        for j in eqn.params.get("branches", ()):
            sub = unwrap(j)
            out.append((sub, tuple(sub.invars)))
    return out


def _inner_extras(eqn, max_len: int, depth: int) -> List[Tuple[int, int]]:
    """Transient (fixed, per_len) bytes a call-like equation holds *beyond*
    its operands: each sub-jaxpr boundary minus the alias-invar bytes,
    clamped at zero componentwise (an inner boundary that already freed an
    operand never credits the caller). ``cond``/``while`` branches combine
    by max implicitly — every branch boundary is a candidate peak."""
    extras: List[Tuple[int, int]] = []
    for sub, alias_invars in _sub_jaxprs(eqn):
        af = al = 0
        for v in alias_invars:
            f, le = _var_size(v, max_len)
            af += f
            al += le
        for f, le in _walk_jaxpr(sub, max_len, depth=depth + 1).boundaries:
            extras.append((max(0, f - af), max(0, le - al)))
    return extras or [(0, 0)]


@dataclasses.dataclass
class _Liveness:
    """One jaxpr's liveness scan result."""
    boundaries: List[Tuple[int, int]]   # candidate (fixed, per_len) peaks
    last_use: Dict[int, int]            # id(var) -> last consuming eqn (-1)
    def_eqn: Dict[int, int]             # id(var) -> defining eqn

    def peak_at(self, length: int) -> int:
        return max(f + le * int(length) for f, le in self.boundaries)

    def argmax_at(self, length: int) -> Tuple[int, int]:
        return max(self.boundaries, key=lambda p: p[0] + p[1] * int(length))


def _walk_jaxpr(jaxpr, max_len: int, *, depth: int = 0,
                free_out_ids: frozenset = frozenset(),
                pinned_ids: frozenset = frozenset()) -> _Liveness:
    """Linear liveness scan of one jaxpr (recursing into sub-jaxprs).

    ``free_out_ids`` are donation-matched outvars (they write into donated
    storage — zero new bytes); ``pinned_ids`` are their donated invars
    (live to the end: their storage *is* the output)."""
    if depth > 32:
        raise RecursionError("memcheck: sub-jaxpr nesting exceeds 32 — "
                             "refusing to walk further (cyclic jaxpr?)")
    last_use: Dict[int, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if not _is_literal(v):
                last_use[id(v)] = i
    def_eqn: Dict[int, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.outvars:
            def_eqn[id(v)] = i
    out_ids = {id(v) for v in jaxpr.outvars if not _is_literal(v)}

    live_f = live_l = 0
    live_ids: set = set()

    def add(v):
        nonlocal live_f, live_l
        if _is_literal(v) or id(v) in live_ids:
            return
        live_ids.add(id(v))
        f, le = _var_size(v, max_len)
        live_f += f
        live_l += le

    def drop(v):
        nonlocal live_f, live_l
        if _is_literal(v) or id(v) not in live_ids:
            return
        live_ids.discard(id(v))
        f, le = _var_size(v, max_len)
        live_f -= f
        live_l -= le

    for v in list(jaxpr.constvars) + list(jaxpr.invars):
        add(v)
    boundaries = [(live_f, live_l)]
    for i, eqn in enumerate(jaxpr.eqns):
        # outputs materialize while the operands are still held
        for v in eqn.outvars:
            if id(v) not in free_out_ids:
                add(v)
        for ef, el in _inner_extras(eqn, max_len, depth):
            boundaries.append((live_f + ef, live_l + el))
        # operands whose last consumer this is die now — unless they are
        # outputs, or pinned donated storage
        for v in eqn.invars:
            if (not _is_literal(v) and last_use.get(id(v)) == i
                    and id(v) not in out_ids and id(v) not in pinned_ids):
                drop(v)
        # an output nothing ever reads (and that isn't returned) frees
        # immediately
        for v in eqn.outvars:
            if id(v) not in last_use and id(v) not in out_ids:
                drop(v)
    return _Liveness(boundaries, last_use, def_eqn)


# --------------------------------------------------------------- donation
def _match_donations(entry: TracedEntry,
                     live: _Liveness) -> Tuple[Dict[int, int],
                                               frozenset, frozenset,
                                               List[Tuple[int, str]]]:
    """Greedily alias each donated invar to an output XLA could actually
    write into its storage: same shape+dtype, and the output's defining
    equation at-or-after the donated buffer's last read (equality is the
    healthy in-place consume-produce — scan carries, scatter updates).

    Returns (matches, free_out_ids, pinned_invar_ids, dead list) where
    ``dead`` carries QL402 reasons for donations that buy nothing."""
    jaxpr = entry.closed.jaxpr
    invars = jaxpr.invars
    # candidate outputs: real vars defined by an equation (an outvar that is
    # itself an invar is QL203's returned-unchanged case, not reusable
    # storage; a literal output occupies nothing)
    candidates = [(pos, v) for pos, v in enumerate(jaxpr.outvars)
                  if not _is_literal(v) and id(v) in live.def_eqn]
    taken: set = set()
    matches: Dict[int, int] = {}
    free_ids: set = set()
    pinned: set = set()
    dead: List[Tuple[int, str]] = []
    for i in sorted(entry.donated):
        var = invars[i]
        aval = var.aval
        lu = live.last_use.get(id(var), -1)
        shape_hits = [(pos, v) for pos, v in candidates
                      if pos not in taken and v.aval.shape == aval.shape
                      and v.aval.dtype == aval.dtype]
        viable = [(pos, v) for pos, v in shape_hits
                  if live.def_eqn[id(v)] >= lu]
        if viable:
            pos, v = min(viable, key=lambda pv: live.def_eqn[id(pv[1])])
            taken.add(pos)
            matches[i] = pos
            free_ids.add(id(v))
            pinned.add(id(var))
        elif shape_hits:
            dead.append((i, (
                "donated buffer cannot be reused: every same-shape/dtype "
                f"output (e.g. output #{shape_hits[0][0]}, defined at eqn "
                f"{live.def_eqn[id(shape_hits[0][1])]}) materializes while "
                f"the donated buffer is still read (last use eqn {lu}) — "
                "the lifetimes overlap, so XLA keeps both copies")))
        else:
            dead.append((i, (
                "donated buffer cannot be reused: no output shares its "
                f"shape {tuple(aval.shape)} and dtype {aval.dtype} — the "
                "donation frees nothing; drop it, or return the updated "
                "buffer so XLA can write in place")))
    return matches, frozenset(free_ids), frozenset(pinned), dead


# ------------------------------------------------------------- per-entry
def _where(entry: TracedEntry, tail: str = "mem") -> str:
    return f"jaxpr:{entry.name}#{tail}"


def _live_label_bytes(entry: TracedEntry, glob: str) -> int:
    """Bytes of the entry's DCE-live invars whose label matches ``glob`` —
    what the compiled program actually reads for that group."""
    from repro.analysis.jaxpr_checks import _used_invars

    used = _used_invars(entry.closed)
    return sum(_aval_bytes(v.aval)
               for v, lbl, u in zip(entry.closed.jaxpr.invars, entry.labels,
                                    used)
               if u and fnmatch.fnmatch(lbl, glob))


def check_memory(entry: TracedEntry) -> Tuple[Report, Dict[str, Any]]:
    """Liveness-scan one entry: QL401/QL402/QL403/QL404 findings plus the
    machine-readable record ``--mem-json`` aggregates."""
    rep = Report()
    mem = entry.mem
    max_len = mem.max_len if mem else 0

    pre = _walk_jaxpr(entry.closed.jaxpr, max_len)
    matches, free_ids, pinned, dead = _match_donations(entry, pre)
    for i, reason in dead:
        rep.add("QL402", "dead-donation", "error",
                _where(entry, entry.labels[i]), reason)
    live = _walk_jaxpr(entry.closed.jaxpr, max_len,
                       free_out_ids=free_ids, pinned_ids=pinned)

    record: Dict[str, Any] = {
        "entry": entry.name,
        "max_len": max_len,
        "donated": len(entry.donated),
        "donation_matched": len(matches),
        "donation_dead": len(dead),
    }
    peak_trace = live.peak_at(max_len)
    pf, pl = live.argmax_at(max_len)
    record.update(peak_trace_bytes=peak_trace, peak_fixed_bytes=pf,
                  peak_bytes_per_token=pl)

    if mem is None:
        rep.add("QL401", "hbm-budget", "info", _where(entry),
                f"no MemContract declared — measured peak-live "
                f"{peak_trace / _MIB:.3f} MiB "
                f"({pf / _MIB:.3f} fixed + {pl} B/token), unenforced")
        return rep, record

    budget_trace = mem.budget_at(max_len)
    record.update(budget_trace_bytes=budget_trace, slots=mem.slots,
                  envelope_len=mem.envelope_len, note=mem.note)
    if peak_trace > budget_trace:
        rep.add("QL401", "hbm-budget", "error", _where(entry),
                f"peak-live {peak_trace / _MIB:.3f} MiB exceeds the "
                f"declared budget {budget_trace / _MIB:.3f} MiB at the "
                f"traced window L={max_len} "
                f"(peak = {pf / _MIB:.3f} MiB fixed + {pl} B/token; "
                f"budget: {mem.note or 'undocumented'})")
    if mem.envelope_len:
        peak_env = live.peak_at(mem.envelope_len)
        budget_env = mem.budget_at(mem.envelope_len)
        record.update(peak_envelope_bytes=peak_env,
                      budget_envelope_bytes=budget_env)
        if peak_env > budget_env:
            ef, el = live.argmax_at(mem.envelope_len)
            rep.add("QL401", "hbm-budget", "error", _where(entry),
                    f"peak-live {peak_env / _MIB:.1f} MiB exceeds the "
                    f"budget {budget_env / _MIB:.1f} MiB at the production "
                    f"envelope L={mem.envelope_len} (scaled from the "
                    f"L={max_len} trace: {ef / _MIB:.3f} MiB fixed + "
                    f"{el} B/token vs budget {mem.per_len_bytes} B/token)")
        elif peak_trace <= budget_trace:
            rep.add("QL401", "hbm-budget", "info", _where(entry),
                    f"peak-live fits the budget at L={max_len} "
                    f"({peak_trace / _MIB:.3f} <= {budget_trace / _MIB:.3f} "
                    f"MiB) and at the envelope L={mem.envelope_len} "
                    f"({peak_env / _MIB:.1f} <= {budget_env / _MIB:.1f} "
                    "MiB) — the smoke trace proves the production window")
    elif peak_trace <= budget_trace:
        rep.add("QL401", "hbm-budget", "info", _where(entry),
                f"peak-live {peak_trace / _MIB:.3f} MiB fits the budget "
                f"{budget_trace / _MIB:.3f} MiB")

    # QL403: the jaxpr's live bytes per labeled group vs the accessor claim
    record["expect"] = []
    for measure, glob, expected in mem.expect:
        static = _live_label_bytes(entry, glob)
        record["expect"].append({"measure": measure, "glob": glob,
                                 "expected_bytes": expected,
                                 "static_bytes": static})
        slack = max(4096, int(0.01 * expected))
        if abs(static - expected) > slack:
            rep.add("QL403", "weight-traffic", "error",
                    _where(entry, measure),
                    f"live invars matching {glob!r} move {static} B in the "
                    f"jaxpr but the accessor claims {expected} B "
                    f"(drift {static - expected:+d} B > slack {slack} B) — "
                    "a dead/extra buffer, or the accessor and the jaxpr "
                    "disagree about what serving reads")
        else:
            rep.add("QL403", "weight-traffic", "info",
                    _where(entry, measure),
                    f"{measure}: jaxpr-live {static} B matches the "
                    f"accessor's {expected} B (slack {slack} B)")

    # QL404 (info): allocated-window growth — the paged-KV gap, quantified
    if max_len:
        wl = sum(_var_size(v, max_len)[1]
                 for v in entry.closed.jaxpr.invars)
        record["window_state_bytes_per_token"] = wl
        if wl and mem.envelope_len:
            pinned_env = wl * mem.envelope_len
            per_slot = wl // mem.slots if mem.slots else wl
            record["window_state_envelope_bytes"] = pinned_env
            rep.add("QL404", "cache-growth", "info", _where(entry),
                    f"window state pins {wl} B/token ({per_slot} B/token/"
                    f"slot) scaled by the *allocated* max_len, not the "
                    f"used length — {pinned_env / _MIB:.1f} MiB at the "
                    f"envelope L={mem.envelope_len} even for one-token "
                    "sequences; a paged KV cache reclaims that tail")
    return rep, record


# ----------------------------------------------------- cross-entry checks
def check_kv_static_gap(entries: Sequence[TracedEntry]) -> Report:
    """Prove the int8-KV-vs-bf16-KV HBM gap *statically*: the per-token
    window bytes of the two ``serve_decode`` jaxprs, read off their cache
    invars alone, must put int8 strictly below bf16 — the same claim the
    serve bench measures live (``hbm_per_slot_MiB``)."""
    rep = Report()

    def window_cache_bytes(entry: TracedEntry) -> int:
        ml = entry.mem.max_len if entry.mem else 0
        return sum(_var_size(v, ml)[1]
                   for v, lbl in zip(entry.closed.jaxpr.invars, entry.labels)
                   if fnmatch.fnmatch(lbl, "cache*"))

    int8 = [e for e in entries
            if e.name.startswith("serve_decode") and "bf16-kv" not in e.name]
    bf16 = [e for e in entries if e.name.startswith("serve_decode")
            and "bf16-kv" in e.name]
    if not int8 or not bf16:
        return rep
    bi, bb = window_cache_bytes(int8[0]), window_cache_bytes(bf16[0])
    where = "jaxpr:serve_decode#kv-gap"
    if bi < bb:
        rep.add("QL405", "kv-gap-static", "info", where,
                f"int8 KV pins {bi} B/token vs bf16's {bb} B/token "
                f"({bb / max(bi, 1):.2f}x), proven from the jaxprs alone — "
                "the static counterpart of the serve bench's "
                "hbm_per_slot_MiB gap")
    else:
        rep.add("QL405", "kv-gap-static", "error", where,
                f"int8 KV cache pins {bi} B/token, NOT below bf16's "
                f"{bb} B/token — the int8 cache stopped paying for itself "
                "(scales outgrew the codes, or the bf16 path shrank)")
    return rep


# ------------------------------------------------------- bench-row check
def check_bench_rows(paths: Sequence[str], log=print) -> Report:
    """QL403 against the *live* benchmark artifacts: rebuild the bench-LM's
    static byte expectations with the same accessors and compare them to
    the ``--json`` rows ``benchmarks.run`` wrote (``decode/*``'s
    weight_MiB_per_step; ``serve/decode/*``'s hbm_per_slot_MiB). Importing
    ``benchmarks.common`` requires the repo root on sys.path / as cwd —
    the CI analysis job provides both."""
    rep = Report()
    records: List[Dict[str, Any]] = []
    for p in paths:
        with open(p) as fh:
            records.extend(json.load(fh))
    rows = {r["name"]: r for r in records}

    import jax.numpy as jnp

    from benchmarks import common
    from repro.core import QuantRecipe
    from repro.core.qtensor import tree_weight_bytes
    from repro.serve import kv as skv
    from repro.serve.engine import EngineConfig, init_state

    model, params = common.get_trained_lm()

    # decode/* rows: weight_MiB_per_step must equal tree_weight_bytes of
    # the identically-built params (fp16 row uses the raw tree)
    for tag, bits in (("fp16", None), ("w8", 8), ("w4", 4)):
        row = rows.get(f"decode/{tag}")
        if row is None:
            rep.add("QL403", "weight-traffic", "warning",
                    f"bench:decode/{tag}",
                    "row missing from the bench artifacts — run "
                    "`python -m benchmarks.run --only decode --json ...`")
            continue
        if bits is None:
            pv = params
        else:
            recipe = QuantRecipe(method="rtn", w_bits=bits, a_bits=None,
                                 w_granularity="per_channel", iters=1,
                                 batch_size=16)
            pv, _, _ = common.ptq(model, params, recipe, as_qtensor=True)
        static_mib = tree_weight_bytes(pv) / _MIB
        got = float(row["weight_MiB_per_step"])
        # the row prints 3 decimals; 1% covers accessor-vs-format rounding
        slack = max(0.002, 0.01 * static_mib)
        if abs(got - static_mib) > slack:
            rep.add("QL403", "weight-traffic", "error", f"bench:decode/{tag}",
                    f"bench row claims {got:.3f} MiB/step but "
                    f"tree_weight_bytes on the same params gives "
                    f"{static_mib:.3f} MiB (slack {slack:.3f}) — the bench "
                    "and the accessor no longer measure the same thing")
        else:
            rep.add("QL403", "weight-traffic", "info", f"bench:decode/{tag}",
                    f"bench {got:.3f} MiB/step == static "
                    f"{static_mib:.3f} MiB")

    # serve/decode/* rows: hbm_per_slot_MiB from the one accessor
    slots, max_len = 4, 64  # bench_serve's config (benchmarks/tables.py)
    per_slot: Dict[str, float] = {}
    for tag, kv_quant, dtype in (("int8-kv", True, None),
                                 ("bf16-kv", False, jnp.bfloat16)):
        row = rows.get(f"serve/decode/{tag}")
        if row is None or "hbm_per_slot_MiB" not in row:
            rep.add("QL403", "weight-traffic", "warning",
                    f"bench:serve/decode/{tag}",
                    "row missing/skipped in the bench artifacts — run "
                    "`python -m benchmarks.run --only serve --json ...`")
            continue
        ecfg = EngineConfig(slots=slots, max_len=max_len, prefill_group=2,
                            kv_quant=kv_quant, dtype=dtype)
        state = init_state(model, ecfg)
        static_mib = skv.hbm_per_slot_bytes(state["cache"], slots) / _MIB
        got = float(row["hbm_per_slot_MiB"])
        per_slot[tag] = got
        slack = max(0.0002, 0.01 * static_mib)
        if abs(got - static_mib) > slack:
            rep.add("QL403", "weight-traffic", "error",
                    f"bench:serve/decode/{tag}",
                    f"bench row claims {got:.4f} MiB/slot but "
                    f"hbm_per_slot_bytes on a freshly-built cache gives "
                    f"{static_mib:.4f} MiB (slack {slack:.4f}) — the row "
                    "and the accessor drifted apart")
        else:
            rep.add("QL403", "weight-traffic", "info",
                    f"bench:serve/decode/{tag}",
                    f"bench {got:.4f} MiB/slot == static "
                    f"{static_mib:.4f} MiB")
    if len(per_slot) == 2 and per_slot["int8-kv"] >= per_slot["bf16-kv"]:
        rep.add("QL403", "weight-traffic", "error", "bench:serve/decode",
                f"measured int8-kv per-slot HBM {per_slot['int8-kv']:.4f} "
                f"MiB is not below bf16-kv's {per_slot['bf16-kv']:.4f} MiB")
    return rep


# ------------------------------------------------------------ mem report
def mem_report_json(records: Sequence[Dict[str, Any]], path: str,
                    log=print) -> None:
    """Write the ``--mem-json`` artifact: per-entry liveness records plus
    the aggregate the roofline's peak-memory term cross-references."""
    envelope_peaks = [r.get("peak_envelope_bytes") for r in records
                      if r.get("peak_envelope_bytes") is not None]
    doc = {
        "entries": list(records),
        "roofline": {
            # the peak-HBM figure repro.roofline charges for serving:
            # max over entries of the envelope-scaled jaxpr peak
            "peak_hbm_bytes_envelope": max(envelope_peaks, default=0),
            "window_bytes_per_token": {
                r["entry"]: r["window_state_bytes_per_token"]
                for r in records
                if r.get("window_state_bytes_per_token")},
            "see": "repro.roofline.analysis (dtype_bytes is shared, so the "
                   "two accountings cannot disagree on byte widths)",
        },
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)
    log(f"memcheck report written to {path}")
