"""Repo-wide default allowlist for quantlint.

Every entry must carry a reason — the allowlist is the place where an
intentional violation is *documented*, not merely silenced. Entries here are
file-scoped globs (line numbers shift too easily under refactors); narrow,
line-level suppressions belong inline as ``# quantlint: ignore[QLxxx]``.

Rule catalog (see ROADMAP "Static analysis" for the prose version):

AST layer (QL1xx, analysis/ast_rules.py):
  QL101 jit-outside-engine        jax.jit outside the engine cache
  QL102 host-cast-in-trace        int()/float()/bool() on tracer values
  QL103 host-entropy-in-trace     time.* / np.random.* in traced code
  QL104 interpret-default-true    interpret=True as a kernel default
  QL105 pallas-missing-divis      pallas_call without a grid-divisibility
                                  guard (pad helper or assert on %)

jaxpr layer (QL2xx, analysis/jaxpr_checks.py):
  QL201 unused-input              pytree leaf passed in but dead in the jaxpr
  QL202 retrace-budget            compile count grows with layers / mesh
  QL203 donation-unsafe           donated buffer aliases another argument
  QL204 f64-promotion             float64 value inside a jitted quant path
  QL205 weak-type-output          weakly-typed output (promotion hazard)
  QL206 sharding-unconstrained    mesh= entry point without a dp-axis
                                  sharding constraint on its streams
  QL207 kernel-fallback           QTensor layout served by the dequantize
                                  fallback instead of a kernel
"""
from __future__ import annotations

from typing import List

from repro.analysis.report import AllowEntry

DEFAULT_ALLOWLIST: List[AllowEntry] = [
    # --- QL101: jax.jit outside the engine cache -------------------------
    AllowEntry(
        "QL101", "src/repro/core/reconstruct.py*",
        "the engine cache itself — every jit here is behind _get_engine / "
        "the schedule LRU, which is what QL101 protects"),
    AllowEntry(
        "QL101", "src/repro/kernels/*",
        "module-level jit'd public kernel wrappers: one callable per kernel, "
        "static block sizes — jit caching is keyed correctly by construction"),
    AllowEntry(
        "QL101", "src/repro/allocate/sensitivity.py*",
        "probe jit is cached per (recipe, mapping) in _PROBE_CACHE keyed by "
        "_probe_key; compile counts are asserted by tests/test_allocate.py"),
    AllowEntry(
        "QL101", "src/repro/launch/quantize.py*",
        "serve_smoke jits prefill/decode once per process at the end of a "
        "launch — no retrace surface"),
    AllowEntry(
        "QL101", "src/repro/launch/dryrun.py*",
        "AOT .lower() cost estimation; compiles are the measurement"),
    AllowEntry(
        "QL101", "src/repro/launch/train.py*",
        "pretraining step jit — one per run, outside the PTQ path"),
    AllowEntry(
        "QL101", "src/repro/analysis/*",
        "the linter's own trace harness: jits entry points once to obtain "
        "their jaxprs"),
]


def default_allowlist() -> List[AllowEntry]:
    return list(DEFAULT_ALLOWLIST)
