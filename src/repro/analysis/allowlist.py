"""Repo-wide default allowlist for quantlint.

Every entry must carry a reason — the allowlist is the place where an
intentional violation is *documented*, not merely silenced. Entries here are
file-scoped globs (line numbers shift too easily under refactors); narrow,
line-level suppressions belong inline as ``# quantlint: ignore[QLxxx]``.

Rule catalog (see ROADMAP "Static analysis" for the prose version):

AST layer (QL1xx, analysis/ast_rules.py):
  QL101 jit-outside-engine        jax.jit outside the engine cache
  QL102 host-cast-in-trace        int()/float()/bool() on tracer values
  QL103 host-entropy-in-trace     time.* / np.random.* in traced code
  QL104 interpret-default-true    interpret=True as a kernel default
  QL105 pallas-missing-divis      pallas_call without a grid-divisibility
                                  guard (pad helper or assert on %)
  QL106 adhoc-host-clock          bare time.time/perf_counter/monotonic in
                                  host code outside repro/obs/ and
                                  benchmarks/ — route timing through
                                  repro.obs (Stopwatch/now()/spans)

jaxpr layer (QL2xx, analysis/jaxpr_checks.py):
  QL201 unused-input              pytree leaf passed in but dead in the jaxpr
  QL202 retrace-budget            compile count grows with layers / mesh
  QL203 donation-unsafe           donated buffer aliases another argument
  QL204 f64-promotion             float64 value inside a jitted quant path
  QL205 weak-type-output          weakly-typed output (promotion hazard)
  QL206 sharding-unconstrained    mesh= entry point without a dp-axis
                                  sharding constraint on its streams
  QL207 kernel-fallback           QTensor layout served by the dequantize
                                  fallback instead of a kernel

meta (analysis/report.py + ast_rules.py):
  QL110 stale-allowlist /         an allowlist entry — or an inline
        stale-inline-ignore       ``quantlint: ignore`` comment — suppressed
                                  nothing on a full run: the excused
                                  violation is gone; drop it (full runs
                                  only: partial layers would see false
                                  staleness)

quantcheck layer (QL3xx, analysis/intervals.py + diffcheck.py +
shardcheck.py — abstract-interpretation numerics verifier and cross-backend
kernel differ):
  QL301 int-overflow              an integer equation's value interval
                                  (contractions envelope-scaled to k_max)
                                  leaves its dtype range; a fitting int
                                  accumulator is reported as a proof (info)
  QL302 grid-saturation           a clamp bound is provably always active —
                                  the quantization grid collapses to a
                                  constant for the declared value ranges
  QL303 scale-underflow           a divisor interval entirely subnormal
                                  (< float32 tiny): the s1*s2*s3 product
                                  flushes to zero and kills FlexRound's
                                  reciprocal-rule gradients
  QL304 kernel-parity             Pallas-interpret vs XLA ref diverge on the
                                  shape lattice (bit-exact for single-tile /
                                  integer paths, tolerance elsewhere), or a
                                  layout dispatched to the wrong kernel
  QL305 lost-psum                 a shard_map collective reduces over the
                                  wrong mesh axis, or an output is declared
                                  replicated over a dp axis nothing reduced
                                  (with check_rep=False hiding it)
  QL306 scan-collective-          a collective inside a donated-carry scan
        unconstrained             body with no sharding constraint anchoring
                                  the reduced value's layout

memcheck layer (QL4xx, analysis/memcheck.py — jaxpr liveness vs per-entry
MemContract HBM budgets; opt-in via ``lint --mem``):
  QL401 hbm-budget                peak-live bytes exceed the entry's declared
                                  budget, at the traced window or scaled to
                                  the production envelope (serve_kv seq_max);
                                  a fitting peak is reported as a proof (info)
  QL402 dead-donation             a donated buffer no output can actually
                                  reuse (shape/dtype mismatch, or every
                                  candidate's lifetime overlaps) — the
                                  silent inverse of QL203
  QL403 weight-traffic            the jaxpr's live bytes for a labeled group
                                  drifted from the accessors' claim
                                  (tree_weight_bytes / hbm_per_slot_bytes),
                                  or from the live bench rows (--bench-rows)
  QL404 cache-growth (info)       window state scaling with the *allocated*
                                  max_len, not the used length — the
                                  quantified paged-KV gap (--mem-json)
  QL405 kv-gap-static             the int8-vs-bf16 per-token KV gap proven
                                  (info) or refuted (error) from the two
                                  serve_decode jaxprs alone
"""
from __future__ import annotations

from typing import List

from repro.analysis.report import AllowEntry

DEFAULT_ALLOWLIST: List[AllowEntry] = [
    # --- QL101: jax.jit outside the engine cache -------------------------
    AllowEntry(
        "QL101", "src/repro/core/reconstruct.py*",
        "the engine cache itself — every jit here is behind _get_engine / "
        "the schedule LRU, which is what QL101 protects"),
    AllowEntry(
        "QL101", "src/repro/kernels/*",
        "module-level jit'd public kernel wrappers: one callable per kernel, "
        "static block sizes — jit caching is keyed correctly by construction"),
    AllowEntry(
        "QL101", "src/repro/allocate/sensitivity.py*",
        "probe jit is cached per (recipe, mapping) in _PROBE_CACHE keyed by "
        "_probe_key; compile counts are asserted by tests/test_allocate.py"),
    AllowEntry(
        "QL101", "src/repro/launch/quantize.py*",
        "serve_smoke jits prefill/decode once per process at the end of a "
        "launch — no retrace surface"),
    AllowEntry(
        "QL101", "src/repro/launch/dryrun.py*",
        "AOT .lower() cost estimation; compiles are the measurement"),
    AllowEntry(
        "QL101", "src/repro/launch/train.py*",
        "pretraining step jit — one per run, outside the PTQ path"),
    AllowEntry(
        "QL101", "src/repro/analysis/*",
        "the linter's own trace harness: jits entry points once to obtain "
        "their jaxprs"),
    AllowEntry(
        "QL101", "src/repro/serve/engine.py*",
        "the serve engine's AOT compiles: every jit is lowered+compiled "
        "exactly once in __init__ (per bucket + decode), compile_count is "
        "frozen afterwards and pinned by the tier-1 no_retrace test"),
]


def default_allowlist() -> List[AllowEntry]:
    return list(DEFAULT_ALLOWLIST)
