"""Structured findings for the quant-correctness linter ("quantlint").

Every analyzer — jaxpr-level (repro.analysis.jaxpr_checks) and AST-level
(repro.analysis.ast_rules) — emits :class:`Finding`s into a :class:`Report`.
A finding carries a stable rule id (``QL1xx`` = AST rules, ``QL2xx`` = jaxpr
rules), a severity, and a location: ``file:line`` for AST findings,
``jaxpr:<entry>#<invar-path>`` for jaxpr findings.

Allowlisting: intentional violations are suppressed by
:class:`AllowEntry` rows — ``(rule, where-glob, reason)`` — either from the
repo-wide default list (:mod:`repro.analysis.allowlist`) or inline
``# quantlint: ignore[QLxxx]`` comments (AST rules only; handled in
ast_rules). Suppressed findings are kept in the report, downgraded to
severity ``info`` with the allowlist reason attached, so ``--verbose`` output
and the JSON artifact still show what was waved through and why.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Iterable, List, Optional, Sequence

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str       # stable id, e.g. "QL201"
    name: str       # short slug, e.g. "unused-input"
    severity: str   # "error" | "warning" | "info"
    where: str      # "src/…/ops.py:104" or "jaxpr:<entry>#<invar path>"
    message: str
    allowlisted: str = ""  # reason, when suppressed by an allowlist entry

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def format(self) -> str:
        tag = f"{self.rule}/{self.name}"
        head = f"{self.severity.upper():7s} {tag:32s} {self.where}"
        body = f"  {self.message}"
        if self.allowlisted:
            body += f"\n  allowlisted: {self.allowlisted}"
        return head + "\n" + body


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    """One allowlist row: suppress ``rule`` findings whose location matches
    ``where`` (fnmatch glob). ``reason`` is mandatory — an allowlist entry
    without a why is a blanket ignore."""
    rule: str
    where: str
    reason: str

    def matches(self, f: Finding) -> bool:
        return (self.rule in (f.rule, f.name, "*")
                and fnmatch.fnmatch(f.where, self.where))


class Report:
    """Ordered collection of findings with allowlist + exit-code semantics."""

    def __init__(self, findings: Optional[Iterable[Finding]] = None):
        self.findings: List[Finding] = list(findings or ())

    def add(self, rule: str, name: str, severity: str, where: str,
            message: str) -> Finding:
        f = Finding(rule, name, severity, where, message)
        self.findings.append(f)
        return f

    def extend(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        return self

    # ------------------------------------------------------------ filtering
    def apply_allowlist(self, entries: Sequence[AllowEntry],
                        report_stale: bool = False) -> "Report":
        """Return a new report with matched findings downgraded to ``info``
        (reason attached); unmatched findings pass through unchanged.

        ``report_stale=True`` additionally errors (QL110) on every allowlist
        entry that suppressed nothing: a stale entry is a standing blanket
        ignore waiting for an unrelated future finding to hide under it.
        Only meaningful when this report covers *all* analysis layers —
        partial runs (``--ast-only`` etc.) would see false staleness.
        """
        out = []
        used: set = set()
        for f in self.findings:
            hit = next((e for e in entries if e.matches(f)), None)
            if hit is not None:
                used.add((hit.rule, hit.where))
                if not f.allowlisted:
                    f = dataclasses.replace(f, severity="info",
                                            allowlisted=hit.reason)
            out.append(f)
        rep = Report(out)
        if report_stale:
            for e in entries:
                if (e.rule, e.where) not in used:
                    rep.add("QL110", "stale-allowlist", "error",
                            f"allowlist:{e.rule}@{e.where}",
                            f"allowlist entry for {e.rule} at {e.where!r} "
                            "matched no finding — the violation it excused "
                            "is gone; drop the entry (reason was: "
                            f"{e.reason})")
        return rep

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if rule in (f.rule, f.name)]

    def exit_code(self) -> int:
        return 1 if self.errors() else 0

    # ------------------------------------------------------------- output
    def pretty(self, verbose: bool = False) -> str:
        shown = [f for f in self.findings
                 if verbose or f.severity != "info"]
        lines = [f.format() for f in shown]
        n_err, n_warn = len(self.errors()), len(self.warnings())
        n_quiet = len(self.findings) - len(shown)
        tail = (f"quantlint: {n_err} error(s), {n_warn} warning(s), "
                f"{len(self.findings)} finding(s) total")
        if n_quiet:
            tail += f" ({n_quiet} info/allowlisted hidden; --verbose shows them)"
        return "\n".join(lines + [tail])

    def to_json(self) -> dict:
        return {
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "n_errors": len(self.errors()),
            "n_warnings": len(self.warnings()),
        }

    def save_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2)

    def __len__(self) -> int:
        return len(self.findings)

    def __iter__(self):
        return iter(self.findings)


def merge(*reports: Report) -> Report:
    out = Report()
    for r in reports:
        out.extend(r)
    return out
