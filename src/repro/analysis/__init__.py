"""quantlint — jaxpr-, AST- and abstract-interpretation-level quant analysis.

Three layers, one CLI (``python -m repro.analysis.lint``):

- AST rules (QL1xx, :mod:`repro.analysis.ast_rules`): repo conventions —
  no ad-hoc ``jax.jit``, no host casts/entropy in traced code, no
  ``interpret=True`` defaults, Pallas divisibility guards.
- jaxpr analyzers (QL2xx, :mod:`repro.analysis.jaxpr_checks` over
  :mod:`repro.analysis.trace` entries): unused inputs, retrace budget,
  donation safety, f64/weak-type promotion, sharding honesty — plus the
  kernel-coverage report (:mod:`repro.analysis.coverage`).
- quantcheck (QL3xx): an interval abstract interpreter over jaxprs
  (:mod:`repro.analysis.intervals` — int-accumulator overflow proofs,
  provable grid saturation, subnormal scale-product underflow), a
  cross-backend differential kernel verifier sweeping every kernel-table
  layout over a shape lattice (:mod:`repro.analysis.diffcheck`), and
  shard-safety checks for lost/wrong-axis collectives
  (:mod:`repro.analysis.shardcheck`).

See ROADMAP "Static analysis" for the rule catalog and allowlist policy.
"""
from repro.analysis.jaxpr_checks import RetraceError, no_retrace
from repro.analysis.report import AllowEntry, Finding, Report

__all__ = ["AllowEntry", "Finding", "Report", "RetraceError", "no_retrace"]
