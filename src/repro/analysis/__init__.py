"""quantlint — jaxpr-, AST- and abstract-interpretation-level quant analysis.

Four layers, one CLI (``python -m repro.analysis.lint``):

- AST rules (QL1xx, :mod:`repro.analysis.ast_rules`): repo conventions —
  no ad-hoc ``jax.jit``, no host casts/entropy in traced code, no
  ``interpret=True`` defaults, Pallas divisibility guards.
- jaxpr analyzers (QL2xx, :mod:`repro.analysis.jaxpr_checks` over
  :mod:`repro.analysis.trace` entries): unused inputs, retrace budget,
  donation safety, f64/weak-type promotion, sharding honesty — plus the
  kernel-coverage report (:mod:`repro.analysis.coverage`).
- quantcheck (QL3xx): an interval abstract interpreter over jaxprs
  (:mod:`repro.analysis.intervals` — int-accumulator overflow proofs,
  provable grid saturation, subnormal scale-product underflow), a
  cross-backend differential kernel verifier sweeping every kernel-table
  layout over a shape lattice (:mod:`repro.analysis.diffcheck`), and
  shard-safety checks for lost/wrong-axis collectives
  (:mod:`repro.analysis.shardcheck`).
- memcheck (QL4xx, :mod:`repro.analysis.memcheck`; opt-in via ``--mem``):
  jaxpr-level liveness against per-entry HBM-budget contracts
  (:class:`repro.analysis.trace.MemContract`) — peak-live vs budget at the
  traced window and the production envelope, donation effectiveness,
  weight-traffic honesty against the repo's byte accessors and live bench
  rows, and the cache-growth (paged-KV gap) report.

See ROADMAP "Static analysis" for the rule catalog and allowlist policy.
"""
from repro.analysis.jaxpr_checks import RetraceError, no_retrace
from repro.analysis.report import AllowEntry, Finding, Report

__all__ = ["AllowEntry", "Finding", "Report", "RetraceError", "no_retrace"]
