"""Kernel-coverage report (QL207): which kernel actually serves each QTensor
layout, proven by recording — not by reading the dispatch code.

The runner temporarily wraps the XLA ref kernels (the ``backend="xla"``
dispatch targets) and both ``dequantize_qtensor`` import sites with
recorders, then drives every ROADMAP kernel-table layout through
``kernels.ops.qtensor_matmul`` and every known conv frontend site through
``QuantCtx.conv2d`` in deploy mode. A layout whose recorded kernel is the
dequantize fallback gets a QL207 warning naming the site, shape and serving
bytes — today that is exactly the conv frontends (whisper, phi3-vision),
which previously fell back in silence.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.report import Report
from repro.analysis.trace import MATMUL_LAYOUTS, _export_qt, matmul_example
from repro.core.qtensor import tree_weight_bytes

FALLBACK = "dequantize-fallback"


@dataclasses.dataclass(frozen=True)
class CoverageRow:
    site: str                # layout name or model-site name
    shape: Tuple[int, ...]   # logical weight shape
    bits: int
    kernel: str              # ref kernel name, or FALLBACK
    weight_bytes: int

    @property
    def fallback(self) -> bool:
        return self.kernel == FALLBACK


def conv_frontend_sites() -> List[Tuple[str, Tuple[int, ...], int]]:
    """(site name, HWIO weight shape, bits) for the stubbed conv frontends,
    at the real architectures' dims: whisper's two 1-D encoder convs
    (kernel 3, mel 80 -> d_model) and phi3-vision's 14x14 CLIP patch embed.
    These are the QTensor sites the serving path cannot kernel yet."""
    from repro.configs import get_config
    sites = []
    wh = get_config("whisper-medium")
    sites.append((f"{wh.name}.encoder.conv1", (1, 3, 80, wh.d_model), 8))
    sites.append((f"{wh.name}.encoder.conv2",
                  (1, 3, wh.d_model, wh.d_model), 8))
    ph = get_config("phi-3-vision-4.2b")
    sites.append((f"{ph.name}.vision.patch_embed", (14, 14, 3, ph.d_model), 8))
    return sites


@contextlib.contextmanager
def _record_kernels(hits: List[str]):
    """Wrap the ref kernels and both dequantize_qtensor import sites so the
    coverage run records which implementation actually executed."""
    import repro.core.context as qctx
    import repro.kernels.ops as kops
    import repro.kernels.ref as ref

    saved = []

    def wrap(mod, attr, label):
        orig = getattr(mod, attr)

        def rec_fn(*a, _orig=orig, _label=label, **kw):
            hits.append(_label)
            return _orig(*a, **kw)

        saved.append((mod, attr, orig))
        setattr(mod, attr, rec_fn)

    for fname in dir(ref):
        if fname.endswith("_ref"):
            wrap(ref, fname, fname)
    wrap(kops, "dequantize_qtensor", FALLBACK)
    wrap(qctx, "dequantize_qtensor", FALLBACK)
    try:
        yield
    finally:
        for mod, attr, orig in saved:
            setattr(mod, attr, orig)


def _record_one(fn) -> str:
    hits: List[str] = []
    with _record_kernels(hits):
        jax.block_until_ready(fn())
    kernels = [h for h in hits if h != FALLBACK]
    return kernels[0] if kernels else FALLBACK


def kernel_coverage() -> Tuple[Report, List[CoverageRow]]:
    from repro.core.context import QuantCtx
    from repro.kernels import ops as kops

    rep = Report()
    rows: List[CoverageRow] = []

    for name, shape, bits, batch_dims, with_a in MATMUL_LAYOUTS:
        x, qt, a_state = matmul_example(name)
        kernel = _record_one(lambda: kops.qtensor_matmul(
            x, qt, a_state=a_state, backend="xla"))
        rows.append(CoverageRow(name, shape, bits, kernel,
                                tree_weight_bytes(qt)))

    for site, shape, bits in conv_frontend_sites():
        qt = _export_qt(shape, bits)
        kh, kw, cin, _ = shape
        x = jax.random.normal(jax.random.key(17),
                              (1, max(kh, 2), max(kw * 4, 8), cin),
                              jnp.float32)
        ctx = QuantCtx(mode="deploy", backend="xla")
        kernel = _record_one(lambda: ctx.conv2d(site, x, qt))
        rows.append(CoverageRow(site, shape, bits, kernel,
                                tree_weight_bytes(qt)))

    for row in rows:
        if row.fallback:
            rep.add("QL207", "kernel-fallback", "warning",
                    f"coverage:{row.site}",
                    f"QTensor {row.shape} ({row.bits}-bit, "
                    f"{row.weight_bytes / 2**20:.2f} MiB served) dispatches "
                    "to the dequantize fallback — correct but unaccelerated "
                    "(no kernel for this layout yet)")
    return rep, rows


def coverage_table(rows: List[CoverageRow]) -> str:
    head = f"{'site/layout':44s} {'shape':>20s} {'bits':>4s} kernel"
    lines = [head, "-" * len(head)]
    for r in rows:
        mark = "  <- fallback" if r.fallback else ""
        lines.append(f"{r.site:44s} {str(r.shape):>20s} {r.bits:>4d} "
                     f"{r.kernel}{mark}")
    return "\n".join(lines)
