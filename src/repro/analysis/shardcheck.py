"""quantcheck layer 3: shard-safety checks (QL305/QL306).

Extends QL206's coarse "some collective or constraint touches the dp axes"
with two structural rules over explicit SPMD regions:

  QL305 lost-psum / wrong-axis collective
      Inside a ``shard_map``: every collective must reduce over at least
      one declared data-parallel axis, and an output declared *replicated*
      over a dp axis that shards an input must actually have been reduced
      over that axis by some collective. shard_map's own replication check
      (``check_rep=True``) proves the latter natively — so the rule only
      fires where that guard was turned off, which is exactly how the
      classic lost-psum ships: per-host losses declared replicated,
      ``check_rep=False`` silencing the one check that would have caught
      it, every host quietly training on a different objective.

  QL306 unconstrained collective in a donated scan body
      A raw collective inside the scan body of a donated-carry entry
      (the recon chunk shape) without any sharding constraint in the same
      body: the GSPMD partitioner has no anchor for the reduced value, so
      layouts drift step-over-step inside donated buffers. The engine's
      stream re-constrain path is the matching fix.
"""
from __future__ import annotations

from typing import Any, Iterable, List, Set, Tuple

from repro.analysis.jaxpr_checks import _all_jaxprs
from repro.analysis.report import Report
from repro.analysis.trace import TracedEntry

#: primitives that reduce/collect across mesh axes
COLLECTIVES = frozenset({
    "psum", "psum2", "pmean", "pmax", "pmin", "all_reduce", "all_gather",
    "all_gather_invariant", "reduce_scatter", "all_to_all",
})


def _axes_of(eqn) -> Tuple[str, ...]:
    ax = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if isinstance(ax, str):
        ax = (ax,)
    return tuple(a for a in ax if isinstance(a, str))


def _body(v) -> Any:
    return v.jaxpr if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns") else v


def _eqns_in(jaxpr) -> Iterable[Any]:
    for j in _all_jaxprs(jaxpr):
        yield from j.eqns


def _names_axes(names) -> Set[str]:
    """All mesh axes mentioned by one shard_map in_names/out_names entry."""
    out: Set[str] = set()
    for axes in names.values():
        out.update(a for a in axes if isinstance(a, str))
    return out


# ------------------------------------------------------------------- QL305
def check_shard_map(entry: TracedEntry) -> Report:
    rep = Report()
    dp = set(entry.dp)
    for jaxpr in _all_jaxprs(entry.closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "shard_map":
                continue
            body = _body(eqn.params["jaxpr"])
            colls = [(e, _axes_of(e)) for e in _eqns_in(body)
                     if e.primitive.name in COLLECTIVES]
            coll_axes: Set[str] = set()
            for _, axes in colls:
                coll_axes.update(axes)
            check_rep = bool(eqn.params.get("check_rep", True))

            if dp:
                for e, axes in colls:
                    if axes and not set(axes) & dp:
                        rep.add(
                            "QL305", "collective-wrong-axis", "error",
                            f"jaxpr:{entry.name}#shard_map/"
                            f"{e.primitive.name}",
                            f"{e.primitive.name} over mesh axes "
                            f"{sorted(axes)} never reduces over a declared "
                            f"data-parallel axis {sorted(dp)} — the "
                            "cross-replica reduction this entry promises "
                            "is running on the wrong axis")

            if not check_rep:
                in_axes: Set[str] = set()
                for names in eqn.params.get("in_names", ()):
                    in_axes |= _names_axes(names)
                for i, names in enumerate(eqn.params.get("out_names", ())):
                    out_axes = _names_axes(names)
                    missing = sorted((in_axes - out_axes)
                                     & (dp or in_axes) - coll_axes)
                    if missing:
                        rep.add(
                            "QL305", "lost-psum", "error",
                            f"jaxpr:{entry.name}#shard_map/out{i}",
                            f"output {i} is declared replicated over mesh "
                            f"axes {missing} that shard an input, but no "
                            "collective reduces over them and "
                            "check_rep=False disabled shard_map's own "
                            "replication proof — each shard returns a "
                            "different value (lost psum)")
    return rep


# ------------------------------------------------------------------- QL306
def check_scan_collectives(entry: TracedEntry) -> Report:
    rep = Report()
    if not entry.donated or entry.mesh is None:
        return rep
    for jaxpr in _all_jaxprs(entry.closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "scan":
                continue
            body = _body(eqn.params["jaxpr"])
            colls = [e for e in _eqns_in(body)
                     if e.primitive.name in COLLECTIVES]
            if not colls:
                continue
            anchored = any(e.primitive.name == "sharding_constraint"
                           for e in _eqns_in(body))
            if not anchored:
                names = sorted({e.primitive.name for e in colls})
                rep.add(
                    "QL306", "scan-collective-unconstrained", "error",
                    f"jaxpr:{entry.name}#scan",
                    f"collective(s) {names} inside the scan body of a "
                    "donated-carry entry with no sharding constraint in "
                    "the same body — the partitioner has no layout anchor "
                    "for the reduced value, so donated-buffer layouts can "
                    "drift across steps; re-constrain the stream inside "
                    "the body (see reconstruct's stream path)")
    return rep


def check_shard_safety(entry: TracedEntry) -> Report:
    """QL305 + QL306 for one traced entry."""
    rep = check_shard_map(entry)
    rep.extend(check_scan_collectives(entry))
    return rep


__all__: List[str] = ["COLLECTIVES", "check_shard_map",
                      "check_scan_collectives", "check_shard_safety"]
