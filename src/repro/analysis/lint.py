"""quantlint CLI — run the AST, jaxpr and quantcheck analyzers over this repo.

    PYTHONPATH=src python -m repro.analysis.lint            # full default run
    PYTHONPATH=src python -m repro.analysis.lint --ast-only # fast, no tracing
    PYTHONPATH=src python -m repro.analysis.lint --decode-smoke   # + smoke LM
    PYTHONPATH=src python -m repro.analysis.lint --seed-bug a_state_drop
    PYTHONPATH=src python -m repro.analysis.lint --diff-full \
        --parity-json parity.json --coverage-json coverage.json

Default run = AST rules over ``src/`` + jaxpr checks (QL2xx) and quantcheck
(QL3xx: interval abstract interpretation + shard safety) on the toy entry
points (recon chunk, probe step, FlexRound apply, every kernel-table
qtensor_matmul layout), the retrace-flatness check, the kernel-coverage
report, and a smoke subset (3 shapes/layout) of the QL304 cross-backend
differential sweep. The sharded recon entry joins automatically when the
process exposes >= 8 devices (CPU: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``--diff-full`` runs the full QL304 shape lattice (>= 20 shapes per layout;
what the analysis-verify CI job runs); ``--parity-json`` /
``--coverage-json`` write the parity matrix and QL207 coverage rows as CI
artifacts. ``--decode-smoke`` additionally quantizes the smoke LM
(export-only) and checks its deploy-mode decode jaxpr.

``--mem`` adds the memcheck layer (QL4xx): jaxpr-level liveness against the
per-entry HBM-budget contracts, donation effectiveness, weight-traffic
honesty and the cache-growth report, over every traced entry plus the serve
engine entries (including the bf16-KV decode variant for the static
int8-vs-bf16 gap proof). ``--mem-json`` writes the liveness records;
``--bench-rows`` (repeatable) cross-checks them against live
``benchmarks.run --json`` artifacts.

``--seed-bug`` re-introduces a known regression to prove the analyzers
still catch it; the run must then exit non-zero: ``a_state_drop`` /
``per_layer_retrace`` (jaxpr layer), ``int8_overflow`` / ``scale_underflow``
/ ``lost_psum`` (quantcheck layer), ``dead_donation`` / ``hbm_blowout``
(memcheck layer; combine with ``--mem``). Seeded runs skip the differential
sweep (they are targeted regression checks, not parity runs).

Full runs (no ``--ast-only``/``--jaxpr-only``/``--seed-bug``) also audit the
suppressions themselves: an allowlist entry — or an inline
``# quantlint: ignore[QLxxx]`` comment — that suppressed nothing errors as
QL110; stale excuses get dropped, not accumulated.

Exit code: 1 if any error-severity finding survives the allowlist, else 0.
Warnings (e.g. QL207 conv fallbacks) never fail the run; they are the
report's job to keep visible.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import List, Optional, Tuple

from repro.analysis import ast_rules, jaxpr_checks
from repro.analysis.allowlist import default_allowlist
from repro.analysis.report import Report, merge

SEED_BUGS = ("a_state_drop", "per_layer_retrace", "int8_overflow",
             "scale_underflow", "lost_psum", "dead_donation", "hbm_blowout")


def repo_paths() -> Tuple[str, str]:
    """(src dir, repo root) resolved from the installed package, so lint
    output paths ("src/repro/...") match the allowlist globs regardless of
    the working directory."""
    import repro
    pkg = os.path.dirname(os.path.abspath(repro.__file__))
    src = os.path.dirname(pkg)
    return src, os.path.dirname(src)


def jaxpr_entries(*, seed_bug: Optional[str] = None,
                  decode_smoke: bool = False, mem: bool = False,
                  log=print) -> List:
    """The default traced-entry set; mesh entry included when the process
    has enough devices for the debug mesh."""
    import jax

    from repro.analysis import trace
    entries = [trace.recon_chunk_entry(), trace.probe_entry(),
               trace.flexround_apply_entry(), *trace.matmul_entries()]
    if seed_bug == "a_state_drop":
        entries.append(trace.qtensor_matmul_entry("w8a8", drop_a_state=True))
    elif seed_bug == "int8_overflow":
        entries.append(trace.int8_overflow_entry())
    elif seed_bug == "scale_underflow":
        entries.append(trace.flexround_apply_entry(underflow=True))
    elif seed_bug == "lost_psum":
        entries.append(trace.lost_psum_entry())
    elif seed_bug == "dead_donation":
        entries.append(trace.dead_donation_entry())
    elif seed_bug == "hbm_blowout":
        entries.append(trace.hbm_blowout_entry())
    if jax.device_count() >= 8:
        from repro.launch.mesh import make_debug_mesh
        entries.append(trace.recon_chunk_entry(mesh=make_debug_mesh()))
    else:
        log("quantlint: < 8 devices — skipping the sharded recon entry "
            "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    if decode_smoke or (mem and seed_bug is None):
        entries.append(trace.deploy_decode_entry())
        # the serving loop: QL201/QL203/QL207 over the engine's bucketed
        # prefill-insert and slot decode step, with the int8 KV-scale
        # range contract so QL303 proves the stored scales stay normal
        entries.append(trace.serve_prefill_entry())
        entries.append(trace.serve_decode_entry())
    if mem and seed_bug is None:
        # the bf16-KV decode variant exists for memcheck's static
        # int8-vs-bf16 per-slot gap proof (QL405)
        entries.append(trace.serve_decode_entry(kv_quant=False))
    return entries


def run_analysis(*, ast_only: bool = False, jaxpr_only: bool = False,
                 seed_bug: Optional[str] = None, decode_smoke: bool = False,
                 mem: bool = False, mem_json: Optional[str] = None,
                 bench_rows: Optional[List[str]] = None,
                 use_allowlist: bool = True, diff_full: bool = False,
                 parity_json: Optional[str] = None,
                 coverage_json: Optional[str] = None, log=print) -> Report:
    """Build the full quantlint report (shared by the CLI and
    ``launch/quantize --analyze``)."""
    from repro.analysis.intervals import check_intervals
    from repro.analysis.shardcheck import check_shard_safety

    # staleness audits (allowlist + inline ignores) are only decidable on a
    # full run: a partial layer never produces the findings an entry or an
    # inline ignore exists for
    full_run = not ast_only and not jaxpr_only and seed_bug is None
    reports = []
    if not jaxpr_only:
        src, root = repo_paths()
        reports.append(ast_rules.lint_tree(src, rel_to=root,
                                           report_stale_ignores=full_run))
    if not ast_only:
        mem_records = []
        entries = jaxpr_entries(seed_bug=seed_bug, decode_smoke=decode_smoke,
                                mem=mem, log=log)
        for entry in entries:
            reports.append(jaxpr_checks.check_entry(entry))
            # quantcheck: interval numerics + shard safety per entry
            reports.append(check_intervals(entry))
            reports.append(check_shard_safety(entry))
            if mem:
                # memcheck: liveness + HBM-budget contracts per entry
                from repro.analysis.memcheck import check_memory
                mem_rep, mem_rec = check_memory(entry)
                reports.append(mem_rep)
                mem_records.append(mem_rec)
        if mem and seed_bug is None:
            from repro.analysis.memcheck import (check_bench_rows,
                                                 check_kv_static_gap)
            reports.append(check_kv_static_gap(entries))
            if bench_rows:
                reports.append(check_bench_rows(bench_rows, log=log))
        if mem and mem_json:
            from repro.analysis.memcheck import mem_report_json
            mem_report_json(mem_records, mem_json, log=log)
        reports.append(jaxpr_checks.check_retrace(
            per_layer=(seed_bug == "per_layer_retrace")))
        from repro.analysis.coverage import coverage_table, kernel_coverage
        cov_rep, cov_rows = kernel_coverage()
        reports.append(cov_rep)
        log("kernel coverage:")
        log(coverage_table(cov_rows))
        if coverage_json:
            with open(coverage_json, "w") as fh:
                json.dump({"rows": [dataclasses.asdict(r) for r in cov_rows]},
                          fh, indent=2)
            log(f"coverage rows written to {coverage_json}")
        if seed_bug is None:
            from repro.analysis.diffcheck import (parity_json as pj,
                                                  parity_table, run_diffcheck)
            diff_rep, rows = run_diffcheck(smoke=not diff_full)
            reports.append(diff_rep)
            log(f"QL304 differential sweep ({'full' if diff_full else 'smoke'}"
                f" lattice, {len(rows)} cells):")
            log(parity_table(rows))
            if parity_json:
                with open(parity_json, "w") as fh:
                    json.dump(pj(rows), fh, indent=2)
                log(f"parity matrix written to {parity_json}")
    rep = merge(*reports)
    if use_allowlist:
        rep = rep.apply_allowlist(default_allowlist(),
                                  report_stale=full_run)
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--ast-only", action="store_true",
                    help="only the QL1xx AST rules (fast, no jax tracing)")
    ap.add_argument("--jaxpr-only", action="store_true",
                    help="only the QL2xx/QL3xx jaxpr checks + kernel coverage")
    ap.add_argument("--decode-smoke", action="store_true",
                    help="also quantize the smoke LM (export-only) and "
                         "check its deploy-mode decode jaxpr")
    ap.add_argument("--diff-full", action="store_true",
                    help="run the full QL304 shape lattice (>= 20 shapes per "
                         "layout) instead of the 3-shape smoke subset")
    ap.add_argument("--mem", action="store_true",
                    help="also run memcheck (QL4xx): jaxpr liveness vs the "
                         "per-entry HBM-budget contracts (adds the serve "
                         "entries + the bf16-KV decode variant)")
    ap.add_argument("--mem-json", default=None, metavar="PATH",
                    help="write the memcheck liveness report to PATH "
                         "(CI artifact; implies nothing without --mem)")
    ap.add_argument("--bench-rows", action="append", default=None,
                    metavar="PATH",
                    help="bench --json artifact(s) to cross-check against "
                         "the static byte accounting (QL403; repeatable; "
                         "requires --mem and the repo root as cwd)")
    ap.add_argument("--seed-bug", choices=SEED_BUGS, default=None,
                    help="re-introduce a known regression; the run must "
                         "exit non-zero")
    ap.add_argument("--no-allowlist", action="store_true",
                    help="report raw findings (skip the default allowlist)")
    ap.add_argument("--verbose", action="store_true",
                    help="also print info/allowlisted findings")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the structured findings to PATH")
    ap.add_argument("--parity-json", default=None, metavar="PATH",
                    help="write the QL304 parity matrix to PATH (CI artifact)")
    ap.add_argument("--coverage-json", default=None, metavar="PATH",
                    help="write the QL207 coverage rows to PATH (CI artifact)")
    args = ap.parse_args(argv)
    if args.ast_only and args.jaxpr_only:
        ap.error("--ast-only and --jaxpr-only are mutually exclusive")

    rep = run_analysis(ast_only=args.ast_only, jaxpr_only=args.jaxpr_only,
                       seed_bug=args.seed_bug,
                       decode_smoke=args.decode_smoke,
                       mem=args.mem, mem_json=args.mem_json,
                       bench_rows=args.bench_rows,
                       use_allowlist=not args.no_allowlist,
                       diff_full=args.diff_full,
                       parity_json=args.parity_json,
                       coverage_json=args.coverage_json)
    print(rep.pretty(verbose=args.verbose))
    if args.json:
        rep.save_json(args.json)
        print(f"findings written to {args.json}")
    return rep.exit_code()


if __name__ == "__main__":
    sys.exit(main())
