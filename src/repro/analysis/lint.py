"""quantlint CLI — run the AST and jaxpr analyzers over this repo.

    PYTHONPATH=src python -m repro.analysis.lint            # full default run
    PYTHONPATH=src python -m repro.analysis.lint --ast-only # fast, no tracing
    PYTHONPATH=src python -m repro.analysis.lint --decode-smoke   # + smoke LM
    PYTHONPATH=src python -m repro.analysis.lint --seed-bug a_state_drop

Default run = AST rules over ``src/`` + jaxpr checks on the toy entry points
(recon chunk, probe step, every kernel-table qtensor_matmul layout), the
retrace-flatness check, and the kernel-coverage report. The sharded recon
entry joins automatically when the process exposes >= 8 devices (CPU: run
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``--decode-smoke`` additionally quantizes the smoke LM (export-only) and
checks its deploy-mode decode jaxpr — this is what the analysis-smoke CI job
runs. ``--seed-bug`` re-introduces a known shipped regression (the PR 5
a_state drop, or a per-layer retrace) to prove the analyzers still catch it;
the run must then exit non-zero.

Exit code: 1 if any error-severity finding survives the allowlist, else 0.
Warnings (e.g. QL207 conv fallbacks) never fail the run; they are the
report's job to keep visible.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Tuple

from repro.analysis import ast_rules, jaxpr_checks
from repro.analysis.allowlist import default_allowlist
from repro.analysis.report import Report, merge

SEED_BUGS = ("a_state_drop", "per_layer_retrace")


def repo_paths() -> Tuple[str, str]:
    """(src dir, repo root) resolved from the installed package, so lint
    output paths ("src/repro/...") match the allowlist globs regardless of
    the working directory."""
    import repro
    pkg = os.path.dirname(os.path.abspath(repro.__file__))
    src = os.path.dirname(pkg)
    return src, os.path.dirname(src)


def jaxpr_entries(*, seed_bug: Optional[str] = None,
                  decode_smoke: bool = False, log=print) -> List:
    """The default traced-entry set; mesh entry included when the process
    has enough devices for the debug mesh."""
    import jax

    from repro.analysis import trace
    entries = [trace.recon_chunk_entry(), trace.probe_entry(),
               *trace.matmul_entries()]
    if seed_bug == "a_state_drop":
        entries.append(trace.qtensor_matmul_entry("w8a8", drop_a_state=True))
    if jax.device_count() >= 8:
        from repro.launch.mesh import make_debug_mesh
        entries.append(trace.recon_chunk_entry(mesh=make_debug_mesh()))
    else:
        log("quantlint: < 8 devices — skipping the sharded recon entry "
            "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    if decode_smoke:
        entries.append(trace.deploy_decode_entry())
    return entries


def run_analysis(*, ast_only: bool = False, jaxpr_only: bool = False,
                 seed_bug: Optional[str] = None, decode_smoke: bool = False,
                 use_allowlist: bool = True, log=print) -> Report:
    """Build the full quantlint report (shared by the CLI and
    ``launch/quantize --analyze``)."""
    reports = []
    if not jaxpr_only:
        src, root = repo_paths()
        reports.append(ast_rules.lint_tree(src, rel_to=root))
    if not ast_only:
        for entry in jaxpr_entries(seed_bug=seed_bug,
                                   decode_smoke=decode_smoke, log=log):
            reports.append(jaxpr_checks.check_entry(entry))
        reports.append(jaxpr_checks.check_retrace(
            per_layer=(seed_bug == "per_layer_retrace")))
        from repro.analysis.coverage import coverage_table, kernel_coverage
        cov_rep, rows = kernel_coverage()
        reports.append(cov_rep)
        log("kernel coverage:")
        log(coverage_table(rows))
    rep = merge(*reports)
    if use_allowlist:
        rep = rep.apply_allowlist(default_allowlist())
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--ast-only", action="store_true",
                    help="only the QL1xx AST rules (fast, no jax tracing)")
    ap.add_argument("--jaxpr-only", action="store_true",
                    help="only the QL2xx jaxpr checks + kernel coverage")
    ap.add_argument("--decode-smoke", action="store_true",
                    help="also quantize the smoke LM (export-only) and "
                         "check its deploy-mode decode jaxpr")
    ap.add_argument("--seed-bug", choices=SEED_BUGS, default=None,
                    help="re-introduce a known regression; the run must "
                         "exit non-zero")
    ap.add_argument("--no-allowlist", action="store_true",
                    help="report raw findings (skip the default allowlist)")
    ap.add_argument("--verbose", action="store_true",
                    help="also print info/allowlisted findings")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the structured findings to PATH")
    args = ap.parse_args(argv)
    if args.ast_only and args.jaxpr_only:
        ap.error("--ast-only and --jaxpr-only are mutually exclusive")

    rep = run_analysis(ast_only=args.ast_only, jaxpr_only=args.jaxpr_only,
                       seed_bug=args.seed_bug,
                       decode_smoke=args.decode_smoke,
                       use_allowlist=not args.no_allowlist)
    print(rep.pretty(verbose=args.verbose))
    if args.json:
        rep.save_json(args.json)
        print(f"findings written to {args.json}")
    return rep.exit_code()


if __name__ == "__main__":
    sys.exit(main())
