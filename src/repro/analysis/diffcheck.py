"""quantcheck layer 2: cross-backend differential kernel verification (QL304).

Sweeps every kernel-table layout over a generated shape lattice — odd and
edge-case K, grid-non-divisible dims, single- and multi-K-tile — and checks
the Pallas kernels (interpret mode: bit-identical kernel semantics without
a TPU) against the pure-jnp refs (``kernels/ref.py``) through the real
dispatcher ``kernels.ops.qtensor_matmul``. Both runs are recorded (reusing
the QL207 coverage recorders plus a Pallas-side wrapper), so each parity
row also *proves* which kernel served the layout — dispatch drift shows up
as a QL304 error, not a silently-green comparison of the wrong kernel.

Exactness policy (empirical and by construction):
  - single-tile float shapes (M <= 128, N <= 128, K <= block_k = 512): both
    paths run one dot_general of *identical shape* -> bit-exact, asserted;
  - the W8A8 integer path: int32 accumulation is associative -> bit-exact
    at any shape, tiled or not;
  - everything else runs under a relative tolerance: a multi-K-tile grid
    re-associates the contraction sum, and even a multi-N-tile grid changes
    the gemm shape XLA's CPU backend sees, which re-vectorizes the
    reduction (observed: one-ulp differences at N = 129, single K step).
    A bit-exact assert there would be asserting float addition is
    associative.

The full lattice (>= 20 shapes per layout) runs in the analysis-verify CI
job; the default lint run sweeps a 3-shape smoke subset per layout.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.coverage import FALLBACK, _record_kernels
from repro.analysis.report import Report
from repro.analysis.trace import MATMUL_LAYOUTS, _a_state_for, _export_qt
from repro.kernels.envelope import check_envelope

_BLOCK_K = 512      # default K tile of every matmul kernel
#: relative error bound for multi-K-tile float paths (empirically ~1e-6 on
#: CPU interpret vs ref; 8x headroom so CI noise never flakes the lint)
_REL_TOL = 8e-6

#: layout -> (ref kernel, pallas kernel) the dispatcher must pick
EXPECTED_KERNELS: Dict[str, Tuple[str, str]] = {
    "w4_packed": ("dequant_matmul_w4_ref", "dequant_matmul_w4"),
    "w4a8_packed": ("dequant_matmul_w4_ref", "dequant_matmul_w4"),
    "w8a8": ("qmatmul_int8_ref", "qmatmul_int8"),
    "w8_weight_only": ("dequant_matmul_w8_ref", "dequant_matmul_w8"),
    "w4_odd_unpacked": ("dequant_matmul_w8_ref", "dequant_matmul_w8"),
    "experts_batched": ("dequant_matmul_batched_ref", "dequant_matmul_batched"),
}

_PALLAS_KERNELS = ("dequant_matmul_w4", "dequant_matmul_w8",
                   "dequant_matmul_batched", "qmatmul_int8")


@dataclasses.dataclass(frozen=True)
class ParityRow:
    """One (layout, shape) cell of the QL304 parity matrix."""
    layout: str
    shape: Tuple[int, int, int, int]   # (e, m, k, n); e = 1 for 2-D layouts
    kernel_ref: str
    kernel_pallas: str
    mode: str                          # "bit-exact" | "tolerance"
    k_steps: int
    max_abs_err: float
    bound: float                       # 0.0 in bit-exact mode
    ok: bool

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# ------------------------------------------------------------ shape lattice
def shape_lattice(layout: str) -> List[Tuple[int, int, int, int]]:
    """(e, m, k, n) sweep for one layout: edge K (1-2 rows/cols), odd K,
    non-block-divisible everything, plus multi-K-tile rows. Every shape is
    inside the layout's envelope (asserted)."""
    ms = (1, 5, 33)
    ns = (8, 24, 120, 129)
    if layout in ("w4_packed", "w4a8_packed"):
        ks = (2, 6, 16, 62, 64, 126, 254, 256, 510, 512, 514, 1026)
    elif layout == "w4_odd_unpacked":
        ks = (3, 5, 33, 63, 127, 255, 333, 511, 513, 1025)
    elif layout in ("w8a8", "w8_weight_only"):
        ks = (1, 7, 24, 48, 127, 128, 255, 384, 512, 640, 1024, 1100)
    elif layout == "experts_batched":
        ks = (4, 6, 16, 62, 64, 126, 128, 254, 256, 512)
    else:
        raise KeyError(layout)
    es = (1, 2, 3, 5) if layout == "experts_batched" else (1,)
    shapes: List[Tuple[int, int, int, int]] = []
    for rep in range(2):   # two passes with shifted m/n pairing -> >= 20 rows
        for i, k in enumerate(ks):
            e = es[(i + rep) % len(es)]
            m = ms[(i + rep) % len(ms)]
            n = ns[(i + 2 * rep) % len(ns)]
            if (e, m, k, n) in shapes:
                n = ns[(i + 2 * rep + 1) % len(ns)]
            shapes.append((e, m, k, n))
    for e, m, k, n in shapes:
        check_envelope(layout, m, k, n, e)
    return shapes


def _layout_row(layout: str):
    for name, _, bits, batch_dims, with_a in MATMUL_LAYOUTS:
        if name == layout:
            return bits, batch_dims, with_a
    raise KeyError(layout)


def _example_at(layout: str, e: int, m: int, k: int, n: int):
    bits, batch_dims, with_a = _layout_row(layout)
    if batch_dims == 1:
        qt = _export_qt((e, k, n), bits, batch_dims=1)
        x = jax.random.normal(jax.random.key(13), (e, m, k), jnp.float32)
    else:
        qt = _export_qt((k, n), bits, batch_dims=0)
        x = jax.random.normal(jax.random.key(13), (m, k), jnp.float32)
    return x, qt, (_a_state_for(x) if with_a else None)


@contextlib.contextmanager
def _record_pallas(hits: List[str]):
    """Record which Pallas kernel ``ops`` dispatches (the interpret-mode
    run); mirrors coverage's ref-side recorder."""
    import repro.kernels.ops as kops
    saved = []
    for fname in _PALLAS_KERNELS:
        orig = getattr(kops, fname)

        def rec_fn(*a, _orig=orig, _label=fname, **kw):
            hits.append(_label)
            return _orig(*a, **kw)

        saved.append((fname, orig))
        setattr(kops, fname, rec_fn)
    try:
        yield
    finally:
        for fname, orig in saved:
            setattr(kops, fname, orig)


def _first_kernel(hits: List[str]) -> str:
    kernels = [h for h in hits if h != FALLBACK]
    return kernels[0] if kernels else (FALLBACK if hits else "none")


# ------------------------------------------------------------------ checks
def check_parity(layout: str, e: int, m: int, k: int, n: int) -> ParityRow:
    """Run one lattice cell through both backends and compare."""
    from repro.kernels import ops as kops

    x, qt, a_state = _example_at(layout, e, m, k, n)
    ref_hits: List[str] = []
    with _record_kernels(ref_hits):
        ref_out = jax.block_until_ready(kops.qtensor_matmul(
            x, qt, a_state=a_state, backend="xla"))
    pl_hits: List[str] = []
    with _record_pallas(pl_hits):
        pl_out = jax.block_until_ready(kops.qtensor_matmul(
            x, qt, a_state=a_state, backend="pallas", interpret=True))

    k_steps = -(-k // min(_BLOCK_K, k))
    integer_path = layout == "w8a8"
    single_tile = m <= 128 and n <= 128 and k <= _BLOCK_K
    bit_exact = integer_path or single_tile
    ref_np = np.asarray(ref_out, np.float32)
    pl_np = np.asarray(pl_out, np.float32)
    err = float(np.max(np.abs(ref_np - pl_np))) if ref_np.size else 0.0
    if bit_exact:
        bound = 0.0
        ok = bool(np.array_equal(ref_np, pl_np))
    else:
        bound = _REL_TOL * max(1.0, float(np.max(np.abs(ref_np))))
        ok = err <= bound
    return ParityRow(
        layout=layout, shape=(e, m, k, n),
        kernel_ref=_first_kernel(ref_hits),
        kernel_pallas=_first_kernel(pl_hits),
        mode="bit-exact" if bit_exact else "tolerance",
        k_steps=k_steps, max_abs_err=err, bound=bound, ok=ok)


def run_diffcheck(layouts: Optional[Tuple[str, ...]] = None, *,
                  smoke: bool = False) -> Tuple[Report, List[ParityRow]]:
    """Differential sweep; ``smoke=True`` trims the lattice to 3 shapes per
    layout (the default lint run; CI's analysis-verify job runs the full
    lattice)."""
    rep = Report()
    rows: List[ParityRow] = []
    names = layouts or tuple(r[0] for r in MATMUL_LAYOUTS)
    for layout in names:
        lattice = shape_lattice(layout)
        if smoke:
            # one edge-K, one odd/middle, one grid-non-divisible
            lattice = lattice[:3]
        exp_ref, exp_pl = EXPECTED_KERNELS[layout]
        for e, m, k, n in lattice:
            row = check_parity(layout, e, m, k, n)
            rows.append(row)
            where = f"diff:{layout}#e{e}m{m}k{k}n{n}"
            if row.kernel_ref != exp_ref or row.kernel_pallas != exp_pl:
                rep.add("QL304", "dispatch-drift", "error", where,
                        f"layout dispatched to ({row.kernel_ref}, "
                        f"{row.kernel_pallas}); the kernel table promises "
                        f"({exp_ref}, {exp_pl}) — the parity result proves "
                        "the wrong kernel")
            elif not row.ok:
                detail = ("bit-exactness" if row.mode == "bit-exact" else
                          f"tolerance {row.bound:.3g}")
                rep.add("QL304", "kernel-parity", "error", where,
                        f"Pallas-interpret vs XLA ref differ by "
                        f"{row.max_abs_err:.3g} (mode {row.mode}, "
                        f"k_steps={row.k_steps}) — {detail} violated; the "
                        "kernel and its ref have diverged")
    return rep, rows


def parity_table(rows: List[ParityRow]) -> str:
    head = (f"{'layout':18s} {'(e,m,k,n)':>18s} {'mode':>10s} "
            f"{'kst':>3s} {'max|err|':>10s} {'bound':>9s}  kernel")
    lines = [head, "-" * len(head)]
    for r in rows:
        mark = "" if r.ok else "  <- FAIL"
        lines.append(
            f"{r.layout:18s} {str(r.shape):>18s} {r.mode:>10s} "
            f"{r.k_steps:>3d} {r.max_abs_err:>10.3g} {r.bound:>9.3g}  "
            f"{r.kernel_pallas}{mark}")
    return "\n".join(lines)


def parity_json(rows: List[ParityRow]) -> dict:
    return {
        "rows": [r.to_json() for r in rows],
        "layouts": sorted({r.layout for r in rows}),
        "n_rows": len(rows),
        "n_fail": sum(1 for r in rows if not r.ok),
    }
