"""jaxpr-level analyzers (QL2xx) over :class:`~repro.analysis.trace.TracedEntry`.

  QL201 unused-input        a pytree leaf passed into the jitted entry is
                            dead in the jaxpr (DCE removes it). This is the
                            analyzer that proves "a_state actually flows into
                            the kernel" — the PR 5 class of bug.
  QL202 retrace-budget      compile counts grow with layer count (or flap
                            with mesh on/off) instead of staying flat under
                            the engine cache.
  QL203 donation-unsafe     a donated carry buffer aliases another argument
                            (same device buffer twice) or is consumed by more
                            than one equation / returned unchanged — XLA may
                            free or overwrite it while still referenced.
  QL204 f64-promotion       a float64 value appears inside the jitted quant
                            path (silent 2x memory + slow path).
  QL205 weak-type-output    an entry output is weakly typed — downstream
                            promotion becomes caller-dependent.
  QL206 sharding-unconstrained  an entry that declares ``mesh=`` contains no
                            sharding constraint (or psum) touching the mesh's
                            data-parallel axes — "sharded" in the docstring
                            only.

``no_retrace`` is the reusable compile-flatness guard (also exposed as a
tier-1 pytest fixture in tests/conftest.py): it snapshots
``engine_stats().compile_count`` plus a process-wide XLA backend-compile
counter, and raises :class:`RetraceError` if the deltas exceed the budget.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterable, List, Optional

import jax

from repro.analysis.report import Report
from repro.analysis.trace import TracedEntry, toy_chain, toy_recipe
from repro.core import reconstruct as rec

try:  # jax internal, but stable across the versions this repo supports
    from jax._src.interpreters import partial_eval as _pe
except ImportError:  # pragma: no cover - older/newer jax layouts
    _pe = None


# ------------------------------------------------------------ jaxpr walking
def _subjaxprs(jaxpr) -> Iterable[Any]:
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):  # ClosedJaxpr
                yield v.jaxpr
            elif hasattr(v, "eqns"):  # raw Jaxpr
                yield v
            elif isinstance(v, (tuple, list)):
                for item in v:
                    if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                        yield item.jaxpr
                    elif hasattr(item, "eqns"):
                        yield item


def _all_jaxprs(jaxpr) -> Iterable[Any]:
    yield jaxpr
    for sub in _subjaxprs(jaxpr):
        yield from _all_jaxprs(sub)


# --------------------------------------------------------- QL201 unused input
def _used_invars(closed) -> List[bool]:
    """Which flat invars the jaxpr actually consumes (transitively, through
    scan/pjit subjaxprs)."""
    jaxpr = closed.jaxpr
    if _pe is not None:
        _, used = _pe.dce_jaxpr(jaxpr, [True] * len(jaxpr.outvars))
        return list(used)
    # fallback: syntactic reachability (no transitive dead-code analysis)
    referenced = set()
    for j in _all_jaxprs(jaxpr):
        for eqn in j.eqns:
            for v in eqn.invars:
                referenced.add(id(v))
    for v in jaxpr.outvars:
        referenced.add(id(v))
    return [id(v) in referenced for v in jaxpr.invars]


def check_unused_inputs(entry: TracedEntry) -> Report:
    import fnmatch
    rep = Report()
    used = _used_invars(entry.closed)
    for label, u in zip(entry.labels, used):
        if u:
            continue
        if any(fnmatch.fnmatch(label, pat) for pat in entry.allow_unused):
            rep.add("QL201", "unused-input", "info",
                    f"jaxpr:{entry.name}#{label}",
                    "dead leaf (explicitly allowed for this entry)")
            continue
        rep.add("QL201", "unused-input", "error",
                f"jaxpr:{entry.name}#{label}",
                "leaf is passed into the jitted entry but dead in the "
                "jaxpr — state silently not consumed (the a_state-drop "
                "failure class)")
    return rep


# ------------------------------------------------------------- QL203 donation
def check_donation(entry: TracedEntry) -> Report:
    rep = Report()
    jaxpr = entry.closed.jaxpr
    outvar_ids = {id(v) for v in jaxpr.outvars}
    for i in sorted(entry.donated):
        var = jaxpr.invars[i]
        n_uses = sum(1 for eqn in jaxpr.eqns
                     for v in eqn.invars if v is var)
        if n_uses > 1:
            rep.add("QL203", "donation-unsafe", "error",
                    f"jaxpr:{entry.name}#{entry.labels[i]}",
                    f"donated buffer consumed by {n_uses} equations — XLA "
                    "may overwrite it while another consumer still reads it")
        if id(var) in outvar_ids:
            rep.add("QL203", "donation-unsafe", "error",
                    f"jaxpr:{entry.name}#{entry.labels[i]}",
                    "donated input returned unchanged — the caller receives "
                    "a handle to a buffer XLA was told it may free")
    # eager layer: the exemplar donated leaves must occupy distinct device
    # buffers (what _dealias guarantees; aliased buffers make XLA reject the
    # donation or, worse, double-donate)
    seen = {}
    for leaf, i in zip(entry.donated_leaves, sorted(entry.donated)):
        try:
            ptr = leaf.unsafe_buffer_pointer()
        except Exception:  # sharded/committed arrays: pointer not exposed
            continue
        if ptr in seen:
            rep.add("QL203", "donation-unsafe", "error",
                    f"jaxpr:{entry.name}#{entry.labels[i]}",
                    f"aliases the device buffer of "
                    f"{entry.labels[seen[ptr]]} — the same storage would be "
                    "donated twice (run states through _dealias)")
        else:
            seen[ptr] = i
    return rep


# ------------------------------------------------- QL204/QL205 promotion
def check_promotion(entry: TracedEntry) -> Report:
    import numpy as np
    rep = Report()
    flagged = set()
    for j in _all_jaxprs(entry.closed.jaxpr):
        for eqn in j.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is not None and dt == np.float64:
                    key = (eqn.primitive.name, str(dt))
                    if key not in flagged:
                        flagged.add(key)
                        rep.add("QL204", "f64-promotion", "error",
                                f"jaxpr:{entry.name}#{eqn.primitive.name}",
                                "float64 value inside the jitted quant path "
                                "(unintended promotion: 2x memory, slow "
                                "path)")
    for i, v in enumerate(entry.closed.jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if getattr(aval, "weak_type", False):
            rep.add("QL205", "weak-type-output", "warning",
                    f"jaxpr:{entry.name}#out[{i}]",
                    "weakly-typed output — downstream dtype promotion "
                    "becomes caller-dependent")
    return rep


# ------------------------------------------------------------ QL206 sharding
def check_sharding(entry: TracedEntry) -> Report:
    rep = Report()
    if entry.mesh is None or not entry.dp:
        return rep
    constrained_axes = set()
    for j in _all_jaxprs(entry.closed.jaxpr):
        for eqn in j.eqns:
            pname = eqn.primitive.name
            if pname == "sharding_constraint":
                spec = getattr(eqn.params.get("sharding"), "spec", ())
                for part in spec or ():
                    parts = part if isinstance(part, tuple) else (part,)
                    constrained_axes.update(p for p in parts if p)
            elif pname in ("psum", "pmean", "all_gather", "all_reduce"):
                axes = eqn.params.get("axes",
                                      eqn.params.get("axis_name", ()))
                if isinstance(axes, str):
                    axes = (axes,)
                constrained_axes.update(axes or ())
    if not constrained_axes.intersection(entry.dp):
        rep.add("QL206", "sharding-unconstrained", "error",
                f"jaxpr:{entry.name}#mesh",
                f"entry declares mesh axes {entry.dp} but its jaxpr carries "
                "no sharding constraint or collective touching them — the "
                "data-parallel contract exists only in the docstring")
    return rep


def check_entry(entry: TracedEntry) -> Report:
    rep = Report()
    rep.extend(check_unused_inputs(entry))
    rep.extend(check_donation(entry))
    rep.extend(check_promotion(entry))
    rep.extend(check_sharding(entry))
    return rep


# ----------------------------------------------------------- QL202 retrace
class RetraceError(AssertionError):
    """Raised by ``no_retrace`` when compile counts move past the budget."""


def _install_backend_listener() -> bool:
    """Count actual XLA backend compiles process-wide (cache hits emit no
    event). Delegates to :mod:`repro.obs.compile_events` — quantlint and
    telemetry share one jax.monitoring subscription, so each compile is
    also attributed to the enclosing telemetry span. Idempotent; returns
    installed-ness."""
    from repro.obs import compile_events
    return compile_events.install()


@contextlib.contextmanager
def no_retrace(budget: int = 0, xla_budget: Optional[int] = None):
    """Assert compile flatness across the enclosed region.

    ``budget`` bounds the growth of ``engine_stats().compile_count`` (the
    engine's own trace-time counters). ``xla_budget``, when given, also
    bounds raw XLA backend compilations (catches retraces in code that does
    not route through the engine counters, e.g. the deploy kernel wrappers);
    leave it None in code that runs eager jnp math with fresh shapes, since
    every new eager shape compiles too.
    """
    from repro.obs import compile_events
    installed = _install_backend_listener()
    s0 = dataclasses.replace(rec.engine_stats())
    b0 = compile_events.backend_compiles()
    yield
    s1 = rec.engine_stats()
    delta = s1.compile_count - s0.compile_count
    bdelta = compile_events.backend_compiles() - b0
    if delta > budget:
        raise RetraceError(
            f"engine compile count grew by {delta} (budget {budget}): "
            f"step +{s1.step_compiles - s0.step_compiles}, "
            f"schedule +{s1.schedule_compiles - s0.schedule_compiles}, "
            f"teacher +{s1.teacher_compiles - s0.teacher_compiles}, "
            f"student +{s1.student_compiles - s0.student_compiles}, "
            f"recon_err +{s1.recon_error_compiles - s0.recon_error_compiles}, "
            f"probe +{s1.probe_compiles - s0.probe_compiles} "
            f"(XLA backend compiles +{bdelta})")
    if xla_budget is not None and installed and bdelta > xla_budget:
        raise RetraceError(
            f"XLA backend compile count grew by {bdelta} "
            f"(budget {xla_budget}) while engine counters moved {delta}")


def _run_chain(blocks, recipe, d: int, mesh=None):
    x = jax.random.normal(jax.random.key(31), (recipe.batch_size, d))
    y = jax.random.normal(jax.random.key(32), (recipe.batch_size, d))
    for b in blocks:
        rec.reconstruct_block(b, recipe, x, y, jax.random.key(0), mesh=mesh)


def check_retrace(per_layer: bool = False, n_small: int = 2,
                  n_large: int = 4, iters: int = 4, d: int = 16,
                  mesh=None) -> Report:
    """Compile counts must stay flat across layer count (and across repeat
    runs under a mesh): warm the engine cache on a short chain, then demand
    zero new compiles for a longer chain of structurally identical blocks.

    ``per_layer=True`` is the seeded regression: blocks with ``apply_key=
    None`` defeat engine sharing, so every layer retraces — QL202 must fire.
    """
    rep = Report()
    token = None if per_layer else "quantlint-retrace"
    recipe = toy_recipe(iters=iters, batch_size=4)
    suffix = "_sharded" if mesh is not None else ""
    _run_chain(toy_chain(n_small, token=token, d=d), recipe, d, mesh)
    try:
        with no_retrace(0):
            _run_chain(toy_chain(n_large, token=token, d=d), recipe, d, mesh)
    except RetraceError as e:
        rep.add("QL202", "retrace-budget", "error",
                f"jaxpr:recon_chain{suffix}#L{n_small}->L{n_large}",
                f"compile counts grew with layer count: {e}")
    else:
        rep.add("QL202", "retrace-budget", "info",
                f"jaxpr:recon_chain{suffix}#L{n_small}->L{n_large}",
                "compile-flat across layer count")
    return rep
