"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per chip; cost_analysis() on the SPMD module is per-device):

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs
  memory_s     = HLO_bytes_per_device / HBM_bw
  collective_s = collective_bytes_per_device / (links * link_bw)

collective bytes are not in cost_analysis: we parse the compiled HLO text and
sum operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from typing import Dict

from repro.configs.shapes import ShapeSpec

PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
LINK_BW = 50e9
ICI_LINKS = 1  # conservative: one link's worth of bisection per chip

# Bytes per element by HLO short dtype name. Sub-byte packed dtypes carry
# fractional entries (XLA packs two s4 codes per byte); shared with the
# memcheck liveness analyzer (repro.analysis.memcheck) so HBM accounting
# uses one table repo-wide.
_DTYPE_BYTES: Dict[str, float] = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# numpy/jax dtype name -> HLO short name, for byte accounting over avals.
NP_TO_HLO = {
    "bool": "pred", "int8": "s8", "uint8": "u8", "int16": "s16",
    "uint16": "u16", "int32": "s32", "uint32": "u32", "int64": "s64",
    "uint64": "u64", "float16": "f16", "bfloat16": "bf16", "float32": "f32",
    "float64": "f64", "complex64": "c64", "complex128": "c128",
    "int4": "s4", "uint4": "u4",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
}


class UnknownDtypeError(ValueError):
    """An HLO/numpy dtype with no byte-width entry reached the HBM
    accounting. Silently defaulting (the old ``.get(dtype, 4)`` path) would
    mis-size sub-byte packed buffers by 8x — add the dtype to
    ``_DTYPE_BYTES`` instead."""


def dtype_bytes(dtype: str) -> float:
    """Bytes per element for an HLO short name (``s8``) or a numpy/jax
    dtype name (``int8``). Fractional for sub-byte packed dtypes; raises
    :class:`UnknownDtypeError` for anything unregistered."""
    key = NP_TO_HLO.get(dtype, dtype)
    try:
        return _DTYPE_BYTES[key]
    except KeyError:
        raise UnknownDtypeError(
            f"no byte-width entry for dtype {dtype!r} — register it in "
            "roofline.analysis._DTYPE_BYTES (sub-byte packed dtypes take "
            "fractional entries; do not default to 4)") from None

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = f32[16,512]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# tuple-shaped collectives: = (f32[..], f32[..]) all-reduce(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = dtype_bytes(dtype)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return math.ceil(n * b)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind byte totals (result-shape bytes, per device)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue  # avoid double counting async start/done pairs
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dt, dm in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dt, dm)
    return out


def model_flops(cfg, shape: ShapeSpec, n_devices: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed globally.
    Decode processes global_batch tokens; train/prefill seq*batch. Train
    includes backward (the 6x already covers fwd+bwd); prefill/decode are
    forward-only => 2*N*D."""
    total, active = cfg.param_count()
    n = active if cfg.is_moe else total
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyze_compiled(compiled, cfg, shape: ShapeSpec, mesh,
                     weights: str = "bf16", mode: str = None,
                     kv: str = "bf16") -> dict:
    """Roofline terms for one cell.

    compute/memory use the analytic structural model (roofline/analytic.py)
    because XLA cost_analysis counts lax.scan bodies once (verified; see
    EXPERIMENTS.md). Collectives use the compiled HLO with while-trip
    correction. Raw HLO cost numbers are kept for reference.
    """
    from repro.launch.sharding import ARCH_MODE, serve_mode
    from repro.roofline import analytic
    from repro.roofline.hlo_parse import collective_bytes_trip_corrected

    if mode is None:
        mode = (ARCH_MODE.get(cfg.name, "tp") if shape.kind == "train"
                else serve_mode(cfg.name))
    # int8 KV is implemented for dense/moe/vlm GQA caches only — don't
    # flatter the archs that still hold bf16 caches (mla/ssm/hybrid/encdec)
    if kv == "int8" and not (cfg.family in ("dense", "moe", "vlm")
                             and not cfg.use_mla):
        kv = "bf16"
    n_dev = mesh.devices.size
    cost = compiled.cost_analysis()
    raw_flops_dev = float(cost.get("flops", 0.0))
    raw_bytes_dev = float(cost.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = collective_bytes_trip_corrected(txt)
    coll_dev = float(sum(coll.values()))

    flops_dev = analytic.flops_cell_total(cfg, shape) / n_dev
    bytes_dev = analytic.hbm_bytes_cell(cfg, shape, weights, mode=mode,
                                        n_dev=n_dev, kv=kv) / n_dev

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / (ICI_LINKS * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = analytic.model_flops_ideal(cfg, shape)
    mf_dev = mf / n_dev
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "hlo_raw_flops_per_device": raw_flops_dev,
        "hlo_raw_bytes_per_device": raw_bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_breakdown": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops_global": mf,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (
            (mf_dev / PEAK_FLOPS) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
    }
