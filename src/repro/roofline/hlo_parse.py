"""HLO text parsing with while-loop trip-count attribution.

XLA's cost_analysis() counts a while (lax.scan) body ONCE, not xtrips —
verified empirically (see EXPERIMENTS.md §Roofline methodology). For
collective bytes we therefore parse the HLO per-computation, attribute each
collective to its enclosing computation, and multiply by the product of trip
counts of every while loop that calls it (nested scans compose).

Trip counts come from the loop condition: jax scans lower to
``compare(counter, constant(L)), direction=LT``; we resolve the s32 constant.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s32\[\]\s+constant\((\d+)\)")
_CMP_RE = re.compile(r"compare\((?:[^)]*%([\w.\-]+))?[^)]*\), direction=LT")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def split_computations(txt: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in txt.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_START.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None and stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _collect_constants(txt: str) -> Dict[str, int]:
    return {m.group(1): int(m.group(2)) for m in _CONST_RE.finditer(txt)}


def _cond_trip_count(cond_name: str, comps: Dict[str, List[str]],
                     consts: Dict[str, int]) -> int:
    """Find the LT-compare bound inside the condition (following one level of
    fusion call indirection)."""
    seen = set()
    stack = [cond_name]
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for line in comps[name]:
            if "compare(" in line and "direction=LT" in line:
                # operands: last %name that resolves to an s32 constant
                for ref in re.findall(r"%([\w.\-]+)", line):
                    if ref in consts:
                        return consts[ref]
            for m in _CALL_RE.finditer(line):
                stack.append(m.group(1))
    return 1


def while_trip_multipliers(txt: str) -> Dict[str, int]:
    """computation name -> product of trip counts of enclosing whiles."""
    comps = split_computations(txt)
    consts = _collect_constants(txt)
    # edges: computation -> called computations (with weight = trips if while)
    mult: Dict[str, int] = {name: 1 for name in comps}

    # build call graph with while-weighted edges, then propagate from roots
    edges: Dict[str, List[Tuple[str, int]]] = {n: [] for n in comps}
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trips = _cond_trip_count(cond, comps, consts)
                edges[name].append((body, trips))
                edges[name].append((cond, trips))
            else:
                for m in _CALL_RE.finditer(line):
                    callee = m.group(1)
                    if callee in comps:
                        edges[name].append((callee, 1))

    # propagate multipliers down the call graph (DAG; cycles guarded)
    import collections
    result: Dict[str, int] = collections.defaultdict(int)

    def dfs(name: str, factor: int, depth: int = 0):
        if depth > 50:
            return
        result[name] = max(result[name], factor)
        for callee, trips in edges.get(name, []):
            dfs(callee, factor * trips, depth + 1)

    roots = [n for n in comps if "main" in n or n.startswith("jit")]
    if not roots:
        roots = list(comps)[:1]
    for r in roots:
        dfs(r, 1)
    return dict(result)


def collective_bytes_trip_corrected(txt: str) -> Dict[str, float]:
    """Per-collective-kind bytes, multiplied by enclosing-scan trip counts."""
    comps = split_computations(txt)
    mults = while_trip_multipliers(txt)
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    for name, lines in comps.items():
        factor = mults.get(name, 1)
        for line in lines:
            if "-done" in line:
                continue
            for kind in COLLECTIVES:
                if re.search(rf"\b{kind}(-start)?\(", line):
                    lhs = line.split("=", 1)[0] + "= " + \
                        line.split("=", 1)[1].split(kind)[0]
                    for dt, dm in _SHAPE_RE.findall(lhs):
                        out[kind] += _shape_bytes(dt, dm) * factor
                    break
    return out
