"""Analytic FLOP/byte model per (arch x shape x weights) cell.

XLA's cost_analysis undercounts lax.scan bodies (counted once, not x trips),
so roofline compute/memory terms come from this structural model; the
compiled HLO still provides the compile proof, peak memory, and the
trip-corrected collective bytes (hlo_parse.py).

Conventions (documented constants, conservative):
- matmul flops = 2*m*n*k; training multiplies matmul work by BWD_MULT=3
  (fwd + 2x bwd) plus REMAT_MULT=1 extra fwd when cfg.remat (full-remat
  policy) => 4x fwd total. MODEL_FLOPS (6*N*D) / analytic then exposes the
  remat + attention + MoE-capacity overheads as a ratio < 1.
- our chunked online-softmax computes the FULL S^2 score matrix for causal
  attention (no block skipping) — counted as implemented, not as ideal.
- activation HBM traffic: ACT_RW tensor read/writes of (T_loc x width) per
  layer; fwd-only ACT_RW=6, training ACT_RW=14 (fwd write+bwd read of
  boundaries + remat recompute traffic).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.shapes import ShapeSpec

BWD_MULT = 3.0
REMAT_EXTRA = 1.0
ACT_RW_FWD = 6.0
ACT_RW_TRAIN = 14.0

_WBYTES = {"bf16": 2.0, "int8": 1.0, "int4": 0.5}


def _layer_linear_params(cfg) -> Dict[str, float]:
    """Per-layer linear param counts: attention, dense-mlp, moe (active,
    incl. capacity padding), shared, router."""
    D, F, Dh = cfg.d_model, cfg.d_ff, cfg.head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        rq, r = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        attn = (D * rq + rq * H * (dn + dr) + D * (r + dr)
                + r * H * (dn + dv) + H * dv * D)
    elif cfg.family == "ssm":
        d_inner = cfg.ssm_expand * D
        attn = 0.0
        mlp = D * (2 * d_inner + 2 * cfg.ssm_state
                   + d_inner // cfg.ssm_headdim) + d_inner * D
        return {"attn": 0.0, "mlp": mlp, "moe_active": 0.0, "router": 0.0}
    else:
        attn = D * H * Dh * 2 + D * Hkv * Dh * 2
    mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    mlp = mult * D * F
    out = {"attn": attn, "mlp": mlp, "moe_active": 0.0, "router": 0.0}
    if cfg.is_moe:
        out["moe_active"] = (cfg.top_k * cfg.capacity_factor
                             * mult * D * cfg.moe_d_ff
                             + cfg.n_shared_experts * mult * D * cfg.moe_d_ff)
        out["router"] = D * cfg.n_experts
    return out


def _weight_bytes_total(cfg, wmode: str) -> float:
    """Total weight bytes (embeddings/norms bf16; linear sites in wmode)."""
    p = _layer_linear_params(cfg)
    D = cfg.d_model
    mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    wb = _WBYTES[wmode]
    lin = 0.0
    if cfg.is_moe:
        n_moe = cfg.n_layers - cfg.first_dense
        lin += cfg.first_dense * (p["attn"] + p["mlp"])
        lin += n_moe * (p["attn"] + cfg.n_experts * mult * D * cfg.moe_d_ff
                        + cfg.n_shared_experts * mult * D * cfg.moe_d_ff)
    else:
        n_attn = cfg.n_layers + cfg.enc_layers
        lin += n_attn * (p["attn"] + p["mlp"])
        if cfg.enc_layers:
            lin += cfg.n_layers * p["attn"]  # cross attention
    emb = cfg.vocab * D * (1 if cfg.tie_embeddings else 2) * 2.0  # bf16
    return lin * wb + emb


def _attn_flops_token(cfg, s_ctx: float, qchunked: bool = True) -> float:
    """Attention score+value flops per token at context length s_ctx.
    qchunked: causal q-chunk KV truncation applies (train/prefill only;
    decode always reads the whole cache)."""
    if cfg.family == "ssm":
        # SSD: intra-chunk quadratic + state passing
        Q = cfg.attn_chunk
        H = cfg.ssm_expand * cfg.d_model // cfg.ssm_headdim
        P, N = cfg.ssm_headdim, cfg.ssm_state
        return 2 * Q * N + 2 * Q * H * P + 4 * N * H * P
    Dh_qk = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.use_mla else cfg.head_dim
    Dh_v = cfg.v_head_dim if cfg.use_mla else cfg.head_dim
    if cfg.local_window:
        s_eff = min(s_ctx, cfg.local_window)
    elif qchunked and s_ctx > cfg.attn_chunk:
        # causal q-chunking truncates each chunk's KV prefix (4 chunks up to
        # 8k, 2 beyond — mirrors models/attention.py)
        n = min(4 if s_ctx <= 8192 else 2, int(s_ctx) // cfg.attn_chunk)
        s_eff = s_ctx * (n + 1) / (2 * n)
    else:
        s_eff = s_ctx
    per_layer = 2 * cfg.n_heads * s_eff * (Dh_qk + Dh_v)
    if cfg.family == "hybrid":
        # attention only in 1/3 of layers (RRA pattern); RG-LRU is linear
        return per_layer / 3.0
    return per_layer


def flops_cell(cfg, shape: ShapeSpec, training: bool) -> float:
    """Global FLOPs for one step, as implemented."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        T = B * S
        s_ctx = float(S)  # chunked impl computes full S^2
    elif shape.kind == "prefill":
        T = B * S
        s_ctx = float(S)
    else:
        T = B  # one token per sequence
        s_ctx = float(S)
    p = _layer_linear_params(cfg)
    per_tok_lin = 0.0
    if cfg.is_moe:
        n_moe = cfg.n_layers - cfg.first_dense
        per_tok_lin += cfg.first_dense * (p["attn"] + p["mlp"])
        per_tok_lin += n_moe * (p["attn"] + p["moe_active"] + p["router"])
        # dispatch + combine einsums: 2 x 2*E*C_frac*D per token
        c_frac = cfg.top_k * cfg.capacity_factor
        per_tok_lin += n_moe * 2 * 2 * c_frac * cfg.d_model
    else:
        per_tok_lin += (cfg.n_layers + cfg.enc_layers) * (p["attn"] + p["mlp"])
        if cfg.enc_layers:
            per_tok_lin += cfg.n_layers * p["attn"]  # cross attn projections
    head = 2 * cfg.d_model * cfg.vocab if shape.kind != "prefill" else 0
    qch = shape.kind != "decode"
    attn = cfg.n_layers * _attn_flops_token(cfg, s_ctx, qchunked=qch)
    if cfg.enc_layers:
        attn += cfg.enc_layers * _attn_flops_token(cfg, s_ctx,
                                                   qchunked=False)  # bidir
        attn += cfg.n_layers * 2 * cfg.n_heads * 1504 * 2 * cfg.head_dim
    fwd = T * (2 * per_tok_lin + attn) + (T * head if training else B * head)
    if training and cfg.mtp:
        fwd *= (cfg.n_layers + 1) / cfg.n_layers  # MTP extra block + head
    return fwd


def flops_cell_total(cfg, shape: ShapeSpec) -> float:
    f = flops_cell(cfg, shape, training=(shape.kind == "train"))
    if shape.kind == "train":
        mult = BWD_MULT + (REMAT_EXTRA if cfg.remat else 0.0)
        return f * (1 + mult)  # fwd + bwd (+ remat recompute)
    return f


def cache_bytes(cfg, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_headdim
        per = (H * cfg.ssm_headdim * cfg.ssm_state * 4
               + (cfg.ssm_conv - 1) * (d_inner + 2 * cfg.ssm_state) * 4)
        return cfg.n_layers * B * per
    if cfg.family == "hybrid":
        W = min(cfg.local_window, S)
        n_attn = cfg.n_layers // 3
        n_rec = cfg.n_layers - n_attn
        return (n_attn * B * W * cfg.n_kv_heads * cfg.head_dim * 2 * 2
                + n_rec * B * cfg.lru_width * 4 * 2)
    if cfg.use_mla:
        return cfg.n_layers * B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    per = B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    tot = cfg.n_layers * per
    if cfg.enc_layers:
        tot += cfg.n_layers * B * 1504 * cfg.n_heads * cfg.head_dim * 2 * 2
    return tot


KV_INT8_FACTOR = 0.52  # int8 codes + per-(token,head) fp32 scale overhead


def hbm_bytes_cell(cfg, shape: ShapeSpec, wmode: str, *, mode: str = "tp",
                   n_dev: int = 256, kv: str = "bf16") -> float:
    """Global HBM traffic for one step (documented structural model).

    mode='dp' replicates weights: every chip reads the full weight set, so
    global weight traffic is wb * n_dev (this is what makes small-model
    decode on a big mesh memory-inefficient — §Perf smollm iteration).
    """
    B, S = shape.global_batch, shape.seq_len
    wb = _weight_bytes_total(cfg, "bf16" if shape.kind == "train" else wmode)
    w_rep = float(n_dev) if mode == "dp" else 1.0
    cb = cache_bytes(cfg, shape) * (KV_INT8_FACTOR if kv == "int8" else 1.0)
    dtype_b = 2.0
    if shape.kind == "train":
        T = B * S
        # params read fwd+bwd, grads written, adam moments r/w (bf16 moments)
        w_traffic = (wb * 2 + wb * 1 + wb * 2) * w_rep
        act = ACT_RW_TRAIN * T * cfg.d_model * cfg.n_layers * dtype_b
        return w_traffic + act
    if shape.kind == "prefill":
        T = B * S
        act = ACT_RW_FWD * T * cfg.d_model * (cfg.n_layers + cfg.enc_layers) \
            * dtype_b
        return wb * w_rep + act + cb  # cache written once
    # decode: weights + full cache read per token + small activations
    act = ACT_RW_FWD * B * cfg.d_model * cfg.n_layers * dtype_b
    return wb * w_rep + cb + act


def model_flops_ideal(cfg, shape: ShapeSpec) -> float:
    """6*N*D / 2*N*D with causal-optimal attention — the 'useful' flops."""
    total, active = cfg.param_count()
    n = active if cfg.is_moe else total
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch
