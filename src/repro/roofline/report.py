"""Render dryrun_results.json into the EXPERIMENTS.md §Dry-run/§Roofline
tables.

Usage: PYTHONPATH=src python -m repro.roofline.report [results.json ...]
Multiple files merge (later files override same cell ids) so hillclimb
variants can be layered over the baseline sweep.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


GiB = 2**30


def load(paths: List[str]) -> Dict[str, dict]:
    cells: Dict[str, dict] = {}
    for p in paths:
        with open(p) as f:
            for r in json.load(f):
                cells[r["cell"]] = r
    return cells


def _fix(cell: dict) -> dict:
    a = cell["analysis"]
    dom = a["bottleneck"]
    hints = {
        "compute": "raise arithmetic intensity (fuse, larger tiles) or "
                   "shard over more chips",
        "memory": "cut bytes: lower-precision weights/cache (FlexRound int8/"
                  "int4), fuse elementwise chains, avoid re-read of "
                  "activations",
        "collective": "reshard to remove resharding collectives, overlap "
                      "comm with compute, compress gradients",
    }
    return hints[dom]


def markdown(cells: Dict[str, dict], mesh_filter: str = "16x16") -> str:
    rows = []
    head = ("| cell | peak GiB/dev | compute s | memory s | collective s | "
            "bottleneck | MODEL/HLO flops | roofline frac | one-line fix |")
    sep = "|" + "---|" * 9
    for cid, r in sorted(cells.items()):
        if r["status"] == "skipped":
            rows.append(f"| {cid} | — | — | — | — | skipped | — | — | "
                        f"{r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {cid} | ERROR {r.get('error', '')[:60]} "
                        "| | | | | | | |")
            continue
        if mesh_filter and f"|{mesh_filter}|" not in f"|{cid}|".replace(
                cid, cid):
            pass
        a = r["analysis"]
        rows.append(
            f"| {cid} | {r['memory']['peak_bytes_per_device']/GiB:.2f} "
            f"| {a['compute_s']:.2e} | {a['memory_s']:.2e} "
            f"| {a['collective_s']:.2e} | {a['bottleneck']} "
            f"| {a['useful_flops_ratio']:.2f} | {a['roofline_fraction']:.4f} "
            f"| {_fix(r)} |")
    return "\n".join([head, sep] + rows)


def summary(cells: Dict[str, dict]) -> str:
    ok = [r for r in cells.values() if r["status"] == "ok"]
    sk = [r for r in cells.values() if r["status"] == "skipped"]
    er = [r for r in cells.values() if r["status"] == "error"]
    lines = [f"{len(ok)} compiled OK, {len(sk)} skipped (per assignment), "
             f"{len(er)} errors."]
    by_b = {}
    for r in ok:
        by_b.setdefault(r["analysis"]["bottleneck"], []).append(r["cell"])
    for b, cs in sorted(by_b.items()):
        lines.append(f"  {b}-bound: {len(cs)} cells")
    worst = sorted(ok, key=lambda r: r["analysis"]["roofline_fraction"])[:5]
    lines.append("  worst roofline fractions: " + ", ".join(
        f"{r['cell']}={r['analysis']['roofline_fraction']:.4f}"
        for r in worst))
    over = [r for r in ok
            if r["memory"]["peak_bytes_per_device"] > 16 * GiB]
    lines.append(f"  cells over 16GiB v5e HBM: {len(over)}")
    for r in sorted(over, key=lambda r: -r["memory"]["peak_bytes_per_device"]):
        lines.append(f"    {r['cell']}: "
                     f"{r['memory']['peak_bytes_per_device']/GiB:.1f} GiB")
    return "\n".join(lines)


def main():
    paths = sys.argv[1:] or ["dryrun_results.json"]
    cells = load(paths)
    print(summary(cells))
    print()
    print(markdown(cells))


if __name__ == "__main__":
    main()
