"""Unified XLA compile accounting: one ``jax.monitoring`` subscription.

Before this module there were two disjoint compile ledgers: the engine's
trace-time counters (``core.reconstruct.engine_stats()``) and quantlint's
private backend-compile listener (``analysis.jaxpr_checks``). Both now read
from here: a single idempotent ``jax.monitoring`` subscription counts every
actual XLA backend compilation (cache hits emit no event) and attributes it
to the innermost open telemetry span — so a retrace shows up as *where*
("serve.prefill", "recon.chunk"), not just *how many*.

``no_retrace(..., xla_budget=)`` consumes :func:`backend_compiles`;
``compile_summary()`` merges both ledgers for launch-time reporting. When
the telemetry sink is enabled each compile also lands as a
``kind="compile"`` JSONL event with its attributed span and duration.
"""
from __future__ import annotations

import threading
from typing import Dict

from repro.obs.telemetry import TELEMETRY

UNATTRIBUTED = "<unattributed>"

_LOCK = threading.Lock()
_INSTALLED = False
_BACKEND_COMPILES = 0
_BY_SPAN: Dict[str, int] = {}


def _on_event(event: str, duration: float, **kw) -> None:
    global _BACKEND_COMPILES
    if "backend_compile" not in event:
        return
    span = TELEMETRY.current_span() or UNATTRIBUTED
    with _LOCK:
        _BACKEND_COMPILES += 1
        _BY_SPAN[span] = _BY_SPAN.get(span, 0) + 1
    if TELEMETRY.enabled:
        TELEMETRY.counter("xla.backend_compiles").inc()
        TELEMETRY.emit({"kind": "compile", "span": span,
                        "dur_s": round(duration, 6)})


def install() -> bool:
    """Register the process-wide listener (idempotent); returns whether the
    monitoring API is available and the listener is live."""
    global _INSTALLED
    if _INSTALLED:
        return True
    try:
        from jax import monitoring
        monitoring.register_event_duration_secs_listener(_on_event)
        _INSTALLED = True
    except Exception:  # pragma: no cover - monitoring API unavailable
        pass
    return _INSTALLED


def backend_compiles() -> int:
    """Raw XLA backend compilations seen since the listener was installed."""
    return _BACKEND_COMPILES


def compiles_by_span() -> Dict[str, int]:
    """Backend compiles keyed by the telemetry span open when they ran."""
    with _LOCK:
        return dict(_BY_SPAN)


def compile_summary() -> Dict:
    """Both ledgers in one dict: the engine's trace-time counters and the
    backend listener's span-attributed counts."""
    import dataclasses

    from repro.core.reconstruct import engine_stats
    st = engine_stats()
    return {
        "engine": dict(dataclasses.asdict(st),
                       compile_count=st.compile_count),
        "xla_backend_compiles": backend_compiles(),
        "by_span": compiles_by_span(),
    }
