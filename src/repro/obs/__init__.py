"""Unified observability layer (ROADMAP "Observability").

Layout:
  telemetry.py       process-global counters/gauges/histograms + nested
                     host-side spans (disabled by default, zero-cost off)
  sink.py            JSONL event sink + RunManifest (run identity stamped
                     into bench rows, checkpoint meta, serve stats)
  serve_metrics.py   per-request lifecycle metrics: queue wait, TTFT,
                     per-bucket prefill histograms, occupancy/backlog
  compile_events.py  the one jax.monitoring backend-compile subscription,
                     attributing each XLA compile to the enclosing span
  profiler.py        --profile wiring for jax.profiler.trace

Everything here is host-side by contract: instrumented jitted callers never
trace through this package (quantlint QL103/QL106 + the tier-1 no_retrace
assertion enforce it).
"""
from repro.obs.sink import (  # noqa: F401
    SCHEMA_VERSION,
    JsonlSink,
    ListSink,
    RunManifest,
    current_manifest,
)
from repro.obs.telemetry import (  # noqa: F401
    TELEMETRY,
    Counter,
    Gauge,
    Histogram,
    Stopwatch,
    counter,
    gauge,
    histogram,
    span,
)
from repro.obs.serve_metrics import ServeMetrics  # noqa: F401
