"""Process-global telemetry: counters, gauges, timing histograms, spans.

Design contract (the part quantlint enforces, see ROADMAP "Observability"):

* **Host-side only.** Spans and metrics are read on the host, around the
  compiled-call boundaries — never inside a jitted/scanned body. Telemetry
  therefore adds zero traced ops: the recon-chunk and serve-decode jaxprs
  are byte-identical with telemetry on or off (pinned by tier-1's
  ``no_retrace(0, xla_budget=0)`` assertion), and QL103 keeps ``time.*``
  out of traced scopes while QL106 keeps ad-hoc clocks out of host code.

* **Negligible overhead when disabled.** ``span()`` returns a shared no-op
  singleton (no allocation, no clock read); counters/gauges are plain
  attribute bumps. The default state is disabled — enabling requires an
  explicit ``TELEMETRY.enable(...)`` (``launch/quantize --telemetry DIR``).

* **Device work is attributed explicitly.** A span measures wall time; jax
  dispatch is async, so a span around a compiled call measures *dispatch*
  unless you opt in: ``sp.block_on(out)`` (or ``span(..., sync=out)``)
  runs ``jax.block_until_ready`` at span exit, folding device completion
  into the span's duration instead of misattributing it to whichever span
  happens to block next.

Span taxonomy (dotted, coarse-to-fine): ``recon.block > recon.chunk``,
``alloc.teacher`` / ``alloc.probe``, ``serve.build``, ``serve.prefill``,
``serve.decode_step``. XLA compiles are attributed to the innermost open
span by :mod:`repro.obs.compile_events`.
"""
from __future__ import annotations

import math
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from repro.obs.sink import SCHEMA_VERSION, RunManifest


def now() -> float:
    """Monotonic host timestamp (seconds) — the sanctioned absolute clock
    for lifecycle timing (queue wait, TTFT) outside this module."""
    return time.perf_counter()


class Stopwatch:
    """The repo's one sanctioned ad-hoc clock (QL106 keeps bare
    ``time.perf_counter`` out of host code outside this module): started on
    construction, read via ``elapsed_s``/``elapsed_us``."""

    __slots__ = ("_t0",)

    def __init__(self):
        self._t0 = time.perf_counter()

    def restart(self) -> None:
        self._t0 = time.perf_counter()

    def elapsed_s(self) -> float:
        return time.perf_counter() - self._t0

    def elapsed_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact-sample timing histogram (bounded reservoir; serving runs emit
    thousands of observations, not millions — keeping the samples makes
    the percentiles exact instead of bucket-quantized)."""

    __slots__ = ("name", "values", "max_samples", "count", "total", "max")

    def __init__(self, name: str, max_samples: int = 65536):
        self.name = name
        self.values: List[float] = []
        self.max_samples = max_samples
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v > self.max:
            self.max = v
        if len(self.values) < self.max_samples:
            self.values.append(v)

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile over the retained samples
        (matches numpy's default method)."""
        if not self.values:
            return 0.0
        vs = sorted(self.values)
        k = (len(vs) - 1) * q / 100.0
        f, c = math.floor(k), math.ceil(k)
        if f == c:
            return vs[int(k)]
        return vs[f] + (vs[c] - vs[f]) * (k - f)

    def summary(self) -> Dict[str, float]:
        return {"count": float(self.count),
                "mean": self.total / self.count if self.count else 0.0,
                "p50": self.percentile(50),
                "p95": self.percentile(95),
                "max": self.max}


class _NullSpan:
    """Shared no-op span handed out while telemetry is disabled: no clock
    read, no allocation beyond the call's own kwargs, every method inert."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def annotate(self, **attrs) -> None:
        pass

    def block_on(self, tree: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "attrs", "parent", "depth", "dur_us",
                 "_tel", "_sync", "_t0")

    def __init__(self, tel: "Telemetry", name: str, sync: Any,
                 attrs: Dict[str, Any]):
        self._tel = tel
        self.name = name
        self.attrs = attrs
        self._sync = sync
        self.parent: Optional[str] = None
        self.depth = 0
        self.dur_us = 0.0

    def annotate(self, **attrs) -> None:
        self.attrs.update(attrs)

    def block_on(self, tree: Any) -> None:
        """Register device values whose completion belongs to this span;
        ``block_until_ready`` runs on them at span exit."""
        self._sync = tree

    def __enter__(self) -> "Span":
        stack = self._tel._stack()
        self.parent = stack[-1].name if stack else None
        self.depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        if self._sync is not None:
            import jax
            jax.block_until_ready(self._sync)
        self.dur_us = (time.perf_counter() - self._t0) * 1e6
        stack = self._tel._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tel._record_span(self, synced=self._sync is not None)
        return False


class Telemetry:
    """Process-global metric registry + span stack (per-thread) + sink."""

    def __init__(self):
        self.enabled = False
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.manifest: Optional[RunManifest] = None
        self._sink = None
        self._lock = threading.Lock()
        self._tls = threading.local()

    # ------------------------------------------------------------- metrics
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            with self._lock:
                c = self.counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            with self._lock:
                g = self.gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            with self._lock:
                h = self.histograms.setdefault(name, Histogram(name))
        return h

    # --------------------------------------------------------------- spans
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, sync: Any = None, **attrs):
        """Nested wall-time span. Disabled mode returns a shared no-op
        context manager — callers never branch on ``enabled``."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, sync, attrs)

    def current_span(self) -> Optional[str]:
        stack = getattr(self._tls, "stack", None)
        return stack[-1].name if stack else None

    def _record_span(self, span: Span, synced: bool) -> None:
        self.histogram(f"span.{span.name}").observe(span.dur_us)
        rec = {"kind": "span", "name": span.name,
               "dur_us": round(span.dur_us, 3), "depth": span.depth,
               "parent": span.parent, "synced": synced}
        if span.attrs:
            rec["attrs"] = span.attrs
        self.emit(rec)

    # ---------------------------------------------------------------- sink
    def emit(self, record: Dict[str, Any]) -> None:
        if self._sink is not None:
            record.setdefault("schema", SCHEMA_VERSION)
            record.setdefault("ts", time.time())
            self._sink.emit(record)

    def enable(self, sink=None, manifest: Optional[RunManifest] = None
               ) -> None:
        self.enabled = True
        self._sink = sink
        self.manifest = manifest
        if manifest is not None and sink is not None:
            sink.emit(manifest.record())
        from repro.obs import compile_events
        compile_events.install()

    def disable(self) -> None:
        self.enabled = False
        if self._sink is not None:
            self._sink.close()
        self._sink = None

    @contextmanager
    def enabled_scope(self, sink=None,
                      manifest: Optional[RunManifest] = None):
        """Enable telemetry for a region, restoring the prior state after —
        used by tests and by the quantlint trace entries (which trace the
        production functions *under* live telemetry to prove instrumentation
        adds zero traced ops)."""
        prev = (self.enabled, self._sink, self.manifest)
        self.enabled = True
        self._sink = sink
        self.manifest = manifest
        if manifest is not None and sink is not None:
            sink.emit(manifest.record())
        try:
            yield self
        finally:
            self.enabled, self._sink, self.manifest = prev

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {n: c.value for n, c in sorted(self.counters.items())},
            "gauges": {n: g.value for n, g in sorted(self.gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self.histograms.items())},
        }

    def reset(self) -> None:
        """Drop accumulated metrics (tests and bench isolation)."""
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()


TELEMETRY = Telemetry()


def span(name: str, sync: Any = None, **attrs):
    return TELEMETRY.span(name, sync=sync, **attrs)


def counter(name: str) -> Counter:
    return TELEMETRY.counter(name)


def gauge(name: str) -> Gauge:
    return TELEMETRY.gauge(name)


def histogram(name: str) -> Histogram:
    return TELEMETRY.histogram(name)
