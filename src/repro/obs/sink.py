"""JSONL event sink + RunManifest: run identity for every emitted record.

Every telemetry event, bench row, PTQ checkpoint meta, and serve stats dict
carries (a brief of) the same ``RunManifest`` so trajectories are comparable
across PRs: two BENCH_*.json files with different ``git_sha`` came from
different trees, and a ``schema_version`` bump marks a record-shape change
(the version is monotonic — readers may ignore unknown fields but must
refuse a *newer* schema they do not understand).

Manifest fields:
  schema_version     monotonic int — bump on any record-shape change
  git_sha            short sha of HEAD (``unknown`` outside a checkout)
  jax_version        jax.__version__
  backend            jax default backend (cpu/gpu/tpu) or the launch flag
  n_devices          jax.device_count()
  mesh               mesh tag (``debug``/``production``/axis string) or None
  recipe_digest      sha1 over the QuantRecipe repr (None outside PTQ)
  allocation_digest  digest of the automatic bit allocation (None if uniform)

The sink itself is append-only JSONL: one JSON object per line, flushed per
record so a crashed run keeps everything emitted before the crash. Records
are stamped with ``schema`` by :class:`repro.obs.telemetry.Telemetry`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


class JsonlSink:
    """Append-only JSONL file sink (one JSON object per line, per-record
    flush so partial runs stay readable)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._fh = open(path, "a")

    def emit(self, record: Dict[str, Any]) -> None:
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        try:
            self._fh.close()
        except ValueError:  # pragma: no cover - already closed
            pass


class ListSink:
    """In-memory sink for tests and the serve benchmark (records list)."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def emit(self, record: Dict[str, Any]) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass


def digest(obj: Any) -> str:
    """Stable short digest of an object's repr (recipes are frozen
    dataclasses, so repr is canonical)."""
    return hashlib.sha1(repr(obj).encode()).hexdigest()[:12]


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_REPO_ROOT,
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except Exception:  # pragma: no cover - git missing entirely
        pass
    return os.environ.get("GIT_SHA", "unknown")


@dataclasses.dataclass(frozen=True)
class RunManifest:
    schema_version: int
    git_sha: str
    jax_version: str
    backend: str
    n_devices: int
    mesh: Optional[str] = None
    recipe_digest: Optional[str] = None
    allocation_digest: Optional[str] = None

    @classmethod
    def collect(cls, backend: Optional[str] = None, mesh: Any = None,
                recipe: Any = None,
                allocation: Optional[dict] = None) -> "RunManifest":
        import jax
        if mesh is not None and not isinstance(mesh, str):
            mesh = ",".join(f"{n}={s}" for n, s in
                            zip(mesh.axis_names, mesh.devices.shape))
        alloc_digest = None
        if allocation:
            alloc_digest = str(allocation.get("digest") or digest(allocation))
        return cls(
            schema_version=SCHEMA_VERSION,
            git_sha=_git_sha(),
            jax_version=jax.__version__,
            backend=backend or jax.default_backend(),
            n_devices=jax.device_count(),
            mesh=mesh,
            recipe_digest=None if recipe is None else digest(recipe),
            allocation_digest=alloc_digest)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunManifest":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def brief(self) -> Dict[str, Any]:
        """The per-row stamp: enough to align a trajectory point with a
        commit without repeating the full manifest on every row."""
        return {"git_sha": self.git_sha,
                "schema_version": self.schema_version}

    def record(self) -> Dict[str, Any]:
        """The manifest as a sink record (the first line of every JSONL)."""
        return {"kind": "manifest", "schema": self.schema_version,
                **self.to_dict()}


_CURRENT: Optional[RunManifest] = None


def current_manifest() -> RunManifest:
    """Process-cached default manifest (git sha + versions + device count).

    Launch paths that know their recipe/mesh build a richer manifest with
    ``RunManifest.collect(...)``; everything that merely needs run identity
    (checkpoint meta, bench rows, serve stats) stamps this one.
    """
    global _CURRENT
    if _CURRENT is None:
        _CURRENT = RunManifest.collect()
    return _CURRENT


# ----------------------------------------------------------------- validation
def validate_events(path: str) -> List[str]:
    """Schema-check a telemetry JSONL: every line parses, carries ``kind`` +
    a ``schema`` no newer than this reader, and at least one manifest record
    with a git sha is present. Returns a list of errors (empty = valid)."""
    errors: List[str] = []
    n, manifests = 0, 0
    try:
        fh = open(path)
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    with fh:
        for i, line in enumerate(fh, 1):
            if not line.strip():
                continue
            n += 1
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{i}: invalid JSON ({e})")
                continue
            if not isinstance(rec, dict) or "kind" not in rec:
                errors.append(f"{path}:{i}: record has no 'kind'")
                continue
            schema = rec.get("schema")
            if not isinstance(schema, int):
                errors.append(f"{path}:{i}: record has no int 'schema'")
            elif schema > SCHEMA_VERSION:
                errors.append(f"{path}:{i}: schema {schema} is newer than "
                              f"this reader ({SCHEMA_VERSION})")
            if rec.get("kind") == "manifest":
                manifests += 1
                if not rec.get("git_sha"):
                    errors.append(f"{path}:{i}: manifest has no git_sha")
    if n == 0:
        errors.append(f"{path}: no records")
    if manifests == 0:
        errors.append(f"{path}: no manifest record — the run has no "
                      "identity; emit RunManifest first")
    return errors


def check_bench(path: str) -> List[str]:
    """Assert every bench JSON record is manifest-stamped (git sha + schema
    version) — the contract that makes BENCH_*.json trajectories comparable
    across PRs."""
    errors: List[str] = []
    try:
        with open(path) as fh:
            records = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(records, list) or not records:
        return [f"{path}: expected a non-empty list of records"]
    for i, rec in enumerate(records):
        m = rec.get("manifest") if isinstance(rec, dict) else None
        if not isinstance(m, dict):
            errors.append(f"{path}[{i}] ({rec.get('name', '?')}): "
                          "no manifest stamp")
            continue
        if not m.get("git_sha"):
            errors.append(f"{path}[{i}]: manifest has no git_sha")
        if not isinstance(m.get("schema_version"), int):
            errors.append(f"{path}[{i}]: manifest has no schema_version")
    return errors


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="validate telemetry JSONL / bench JSON manifests")
    ap.add_argument("--validate", metavar="EVENTS_JSONL", default=None,
                    help="schema-check a telemetry events file")
    ap.add_argument("--check-bench", metavar="BENCH_JSON", default=None,
                    help="assert every bench record is manifest-stamped")
    args = ap.parse_args()
    if not args.validate and not args.check_bench:
        ap.error("pass --validate and/or --check-bench")
    errors: List[str] = []
    if args.validate:
        errors += validate_events(args.validate)
        if not errors:
            n = sum(1 for line in open(args.validate) if line.strip())
            print(f"{args.validate}: {n} records, schema <= "
                  f"{SCHEMA_VERSION}, manifest-stamped: OK")
    if args.check_bench:
        errs = check_bench(args.check_bench)
        errors += errs
        if not errs:
            print(f"{args.check_bench}: all records manifest-stamped: OK")
    for e in errors:
        print(f"error: {e}")
    if errors:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
