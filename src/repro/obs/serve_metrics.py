"""Per-request serving lifecycle metrics (engine + scheduler).

Always-on and host-side: one ``ServeMetrics`` per :class:`ServeEngine`,
fed by the engine (prefill/decode latency, occupancy) and the scheduler
(queue wait, time-to-first-token, backlog, detokenize errors). Histograms
replace the old ``prefill_us[bucket]`` scalar — which overwrote, so only
the last call per bucket survived — and ``ServeEngine.stats()`` /
``Scheduler`` drain the summaries (p50/p95 per bucket and per request).

When the global :data:`repro.obs.telemetry.TELEMETRY` is enabled, each
admitted request additionally emits one ``kind="request"`` JSONL event
(rid, queue_wait_us, ttft_us, bucket) and the occupancy/backlog gauges are
mirrored — the serve benchmark derives its per-request percentile rows
from exactly those sink records.

Request lifecycle and where each metric is measured::

    submit ──queue_wait──> admit(prefill) ──> first token   [ttft ends here]
                                └─> decode steps ... finish

``ttft`` spans submit → end of the admitting prefill call (the prefill
logits already yield token #1, so first-token latency *is* prefill exit).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.obs.telemetry import TELEMETRY, Histogram, now as _now


class ServeMetrics:
    """Host-side request/latency accounting for one serve engine."""

    def __init__(self, telemetry=None):
        self.tel = telemetry or TELEMETRY
        self.queue_wait_us = Histogram("serve.queue_wait_us")
        self.ttft_us = Histogram("serve.ttft_us")
        self.decode_step_us = Histogram("serve.decode_step_us")
        self.prefill_us: Dict[int, Histogram] = {}
        self.occupancy = 0
        self.backlog_depth = 0
        self.detok_errors = 0
        self.requests_admitted = 0
        self.requests_finished = 0
        self._submit_t: Dict[int, float] = {}

    # ------------------------------------------------------------- engine
    def prefill_hist(self, bucket: int) -> Histogram:
        h = self.prefill_us.get(bucket)
        if h is None:
            h = self.prefill_us[bucket] = Histogram(
                f"serve.prefill_us.b{bucket}")
        return h

    def observe_prefill(self, bucket: int, us: float) -> None:
        self.prefill_hist(bucket).observe(us)
        if self.tel.enabled:
            self.tel.histogram(f"serve.prefill_us.b{bucket}").observe(us)

    def observe_decode(self, us: float, tokens: int) -> None:
        self.decode_step_us.observe(us)
        if self.tel.enabled:
            self.tel.histogram("serve.decode_step_us").observe(us)
            self.tel.counter("serve.tokens_emitted").inc(tokens)

    def set_occupancy(self, n: int) -> None:
        self.occupancy = n
        if self.tel.enabled:
            self.tel.gauge("serve.occupancy").set(n)

    def set_backlog(self, n: int) -> None:
        self.backlog_depth = n
        if self.tel.enabled:
            self.tel.gauge("serve.backlog_depth").set(n)

    # ---------------------------------------------------------- scheduler
    def on_submit(self, rid: int) -> None:
        self._submit_t[rid] = _now()

    def on_admitted(self, rid: int, bucket: int, admit_start: float,
                    first_token_t: float) -> None:
        """Called once per request when its admitting prefill returns.
        Queue wait ends when the prefill *starts*; TTFT when it returns
        (prefill emits the request's first token)."""
        self.requests_admitted += 1
        t_sub = self._submit_t.pop(rid, None)
        if t_sub is None:
            return  # admitted directly via engine.admit — no queue to time
        qw_us = max(admit_start - t_sub, 0.0) * 1e6
        ttft_us = max(first_token_t - t_sub, 0.0) * 1e6
        self.queue_wait_us.observe(qw_us)
        self.ttft_us.observe(ttft_us)
        if self.tel.enabled:
            self.tel.histogram("serve.queue_wait_us").observe(qw_us)
            self.tel.histogram("serve.ttft_us").observe(ttft_us)
            self.tel.emit({"kind": "request", "rid": rid, "bucket": bucket,
                           "queue_wait_us": round(qw_us, 3),
                           "ttft_us": round(ttft_us, 3)})

    def on_finished(self, rid: int) -> None:
        self.requests_finished += 1

    def count_detok_error(self) -> None:
        self.detok_errors += 1
        if self.tel.enabled:
            self.tel.counter("serve.detok_errors").inc()

    # ------------------------------------------------------------- drains
    def prefill_summary(self) -> Dict[int, Dict[str, float]]:
        return {b: h.summary() for b, h in sorted(self.prefill_us.items())}

    def request_summary(self) -> Dict[str, Any]:
        return {
            "admitted": self.requests_admitted,
            "finished": self.requests_finished,
            "queue_wait_us": self.queue_wait_us.summary(),
            "ttft_us": self.ttft_us.summary(),
            "decode_step_us": self.decode_step_us.summary(),
            "occupancy": self.occupancy,
            "backlog_depth": self.backlog_depth,
            "detok_errors": self.detok_errors,
        }


def percentiles_from_events(records, kind: str, field: str,
                            ) -> Optional[Dict[str, float]]:
    """Fold sink records (``kind`` match) into a percentile summary of one
    field — how the serve benchmark turns raw ``kind="request"`` JSONL
    events back into TTFT / queue-wait percentile rows."""
    h = Histogram(f"{kind}.{field}")
    for rec in records:
        if rec.get("kind") == kind and field in rec:
            h.observe(rec[field])
    return h.summary() if h.count else None
