"""``--profile`` wiring: a perfetto-loadable ``jax.profiler`` trace dir.

One process-wide trace (jax allows a single active profile): ``start(dir)``
/ ``stop()`` bracket the run, and the hot loops mark themselves with
:func:`annotate` — ``jax.profiler.StepTraceAnnotation`` around each recon
chunk and serve step, a no-op ``nullcontext`` while profiling is off, so
instrumented loops pay nothing by default. Load the emitted directory in
perfetto (ui.perfetto.dev) or TensorBoard's profile plugin.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

_ACTIVE_DIR: Optional[str] = None


def active() -> Optional[str]:
    return _ACTIVE_DIR


def start(trace_dir: str) -> bool:
    """Begin the process-wide profiler trace into ``trace_dir``; returns
    False (with a warning) if the profiler backend refuses, so --profile
    degrades instead of killing the run."""
    global _ACTIVE_DIR
    if _ACTIVE_DIR is not None:
        return True
    import jax
    os.makedirs(trace_dir, exist_ok=True)
    try:
        jax.profiler.start_trace(trace_dir)
    except Exception as e:  # pragma: no cover - backend-dependent
        print(f"profiler: could not start trace ({e}); continuing unprofiled")
        return False
    _ACTIVE_DIR = trace_dir
    return True


def stop() -> Optional[str]:
    """End the trace; returns the trace dir (None if none was active)."""
    global _ACTIVE_DIR
    if _ACTIVE_DIR is None:
        return None
    import jax
    d, _ACTIVE_DIR = _ACTIVE_DIR, None
    try:
        jax.profiler.stop_trace()
    except Exception as e:  # pragma: no cover - backend-dependent
        print(f"profiler: stop_trace failed ({e})")
    return d


@contextlib.contextmanager
def trace(trace_dir: str):
    """Bracket a region with start/stop (the --profile entry point)."""
    started = start(trace_dir)
    try:
        yield
    finally:
        if started:
            stop()


def annotate(name: str, step: Optional[int] = None):
    """Per-iteration marker inside an active trace (recon chunks, serve
    steps). Free when profiling is off."""
    if _ACTIVE_DIR is None:
        return contextlib.nullcontext()
    import jax
    if step is None:
        return jax.profiler.StepTraceAnnotation(name)
    return jax.profiler.StepTraceAnnotation(name, step_num=step)
