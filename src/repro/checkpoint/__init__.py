from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    PTQCheckpointer,
    load_pytree,
    save_pytree,
)
