from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    PTQCheckpointer,
    load_allocation,
    load_pytree,
    save_allocation,
    save_pytree,
)
