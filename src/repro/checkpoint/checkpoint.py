"""Fault-tolerant, mesh-agnostic checkpointing.

Design points for 1000-node deployments:
- **Atomicity**: every save writes to ``<name>.tmp/``, fsyncs, then renames —
  a crash mid-save never corrupts the last good checkpoint.
- **Mesh-agnostic**: arrays are saved as host numpy + a treedef manifest; on
  restore the caller re-applies sharding rules for whatever mesh the restarted
  job has (elastic scaling: restart on a different device count re-shards
  transparently).
- **PTQ granularity**: the reconstruction engine checkpoints per *block*
  (finalized integer weights + LSQ states + activation streams) so a node
  failure resumes at the failed block, not from scratch.
- **QTensor-aware**: integer codes round-trip exactly (no float detour).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import shutil
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qtensor import QTensor

_MANIFEST = "manifest.json"
_DATA = "arrays.npz"
_TREE = "tree.pkl"


# ------------------------------------------------------------- pytree io
def _to_host(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(x), tree)


def save_pytree(path: str, tree: Any, meta: Optional[dict] = None) -> None:
    """Atomic save of an arbitrary pytree (QTensor leaves supported)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    host = _to_host(tree)
    leaves, treedef = jax.tree.flatten(host)
    np.savez(os.path.join(tmp, _DATA),
             **{f"a{i}": leaf for i, leaf in enumerate(leaves)})
    with open(os.path.join(tmp, _TREE), "wb") as f:
        pickle.dump(treedef, f)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump({"n_leaves": len(leaves), "meta": meta or {}}, f)
    # fsync directory contents then atomically swap into place
    for fn in os.listdir(tmp):
        fd = os.open(os.path.join(tmp, fn), os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str) -> Tuple[Any, dict]:
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    with open(os.path.join(path, _TREE), "rb") as f:
        treedef = pickle.load(f)
    data = np.load(os.path.join(path, _DATA))
    leaves = [data[f"a{i}"] for i in range(manifest["n_leaves"])]
    return jax.tree.unflatten(treedef, leaves), manifest["meta"]


def exists(path: str) -> bool:
    return os.path.isdir(path) and os.path.exists(os.path.join(path, _MANIFEST))


# ----------------------------------------------------- allocation artifacts
_ALLOCATION = "allocation.json"


def save_allocation(directory: str, report: dict) -> str:
    """Atomically persist a JSON-able allocation report next to the PTQ
    state (``<dir>/allocation.json``) so a resumed run can validate it is
    quantizing under the same bit allocation (see repro.allocate.report)."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, _ALLOCATION)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    return path


def load_allocation(directory: str) -> Optional[dict]:
    path = os.path.join(directory, _ALLOCATION)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


# ----------------------------------------------------------- train ckpts
class CheckpointManager:
    """Rolling step checkpoints for the training loop.

    ``save(step, state)`` / ``restore(shardings=None)``. ``shardings`` is a
    pytree of NamedSharding applied on load (elastic re-shard).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and exists(os.path.join(self.dir, d)):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def save(self, step: int, state: Any, meta: Optional[dict] = None) -> str:
        path = self._step_dir(step)
        save_pytree(path, state, dict(meta or {}, step=step))
        for old in self.all_steps()[:-self.keep]:
            shutil.rmtree(self._step_dir(old), ignore_errors=True)
        return path

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings: Any = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        state, meta = load_pytree(self._step_dir(step))
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else
                jnp.asarray(x), state, shardings,
                is_leaf=lambda l: isinstance(l, np.ndarray))
        else:
            state = jax.tree.map(jnp.asarray, state)
        return state, meta


# ------------------------------------------------------------- PTQ ckpts
@dataclasses.dataclass
class _PTQState:
    next_block: int
    finalized: list
    astates: dict
    reports: list
    x_fp: Any
    x_q: Any


class PTQCheckpointer:
    """Per-block reconstruction state (used by core.reconstruct)."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    @property
    def path(self) -> str:
        return os.path.join(self.dir, "ptq_state")

    def save(self, next_block: int, finalized, astates, reports, x_fp, x_q,
             plans: Optional[list] = None, engine: Optional[str] = None,
             allocation: Optional[dict] = None):
        """``plans``: per-finalized-block {site: SitePlan.summary()} dicts —
        recorded so a resume under different rules fails loudly instead of
        silently mixing bit-widths. ``engine`` records which reconstruction
        engine produced the finalized blocks (informational). ``allocation``:
        summary of the automatic bit allocation that emitted the recipe's
        rules (``AllocationReport.meta()``) — a resume under a different
        allocation fails loudly with the allocation named."""
        tree = {
            "finalized": finalized,
            "astates": astates,
            "x_fp": x_fp,
            "x_q": x_q,
        }
        from repro.obs.sink import current_manifest
        meta = {
            "next_block": next_block,
            # BlockReport.to_json keeps the loss/mse trajectories (JSON-safe
            # float lists) — plain asdict would hand json.dump device arrays
            "reports": [r.to_json() for r in reports],
            "plans": plans or [],
            "engine": engine,
            "allocation": allocation,
            # provenance: which code/runtime produced this partial state
            "manifest": current_manifest().to_dict(),
        }
        save_pytree(self.path, tree, meta)

    def load(self, blocks, recipe, allocation: Optional[dict] = None):
        if not exists(self.path):
            return None
        tree, meta = load_pytree(self.path)
        from repro.core.reconstruct import BlockReport, site_plans
        saved_alloc = meta.get("allocation")

        def _alloc_tag(alloc):
            if not alloc:
                return "no allocation"
            return (f"allocation {alloc.get('name', '?')!r} "
                    f"(digest {str(alloc.get('digest', '?'))[:12]})")

        if (allocation or saved_alloc) and (
                (allocation or {}).get("digest")
                != (saved_alloc or {}).get("digest")):
            raise ValueError(
                f"PTQ resume mismatch: checkpoint was written under "
                f"{_alloc_tag(saved_alloc)} but this run quantizes under "
                f"{_alloc_tag(allocation)}; re-run the allocator probe or "
                "restart with a fresh checkpoint dir")
        for i, saved in enumerate(meta.get("plans", [])):
            if i >= len(blocks):
                break
            now = {n: p.summary() for n, p in
                   site_plans(blocks[i], recipe).items()}
            if now != saved:
                raise ValueError(
                    f"PTQ resume mismatch: block {i} ({blocks[i].name}) was "
                    f"finalized under per-site plans {saved} (emitted by "
                    f"{_alloc_tag(saved_alloc)}) but the current recipe "
                    f"resolves to {now}; restart with matching rules "
                    "or a fresh checkpoint dir")
        # BlockReport.from_json tolerates report-schema drift across
        # releases: unknown keys from a newer writer are dropped, missing
        # keys fall back to field defaults
        reports = [BlockReport.from_json(r) for r in meta["reports"]]
        finalized = [jax.tree.map(jnp.asarray, f) for f in tree["finalized"]]
        astates = jax.tree.map(jnp.asarray, tree["astates"])
        return (meta["next_block"], finalized, astates, reports,
                jnp.asarray(tree["x_fp"]), jnp.asarray(tree["x_q"]))
