"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick; applied to the PTQ reconstruction's psum'd gradients and to the
pretraining loop's data-parallel all-reduce).

int8 block-quantized all-reduce with error feedback:
  1. g_eff = g + residual
  2. q = int8_blockquant(g_eff); residual' = g_eff - dequant(q)
  3. all-reduce dequant(q) (8x fewer bytes on the wire than fp32; the ICI
     collective term in the roofline drops proportionally)

Error feedback keeps the compression unbiased over time (Seide et al. '14).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adam import _dq8, _q8


def get_shard_map():
    """Version-compatible ``shard_map``: top-level ``jax.shard_map`` on newer
    jax, ``jax.experimental.shard_map.shard_map`` on older releases. Single
    accessor for every caller that wraps :func:`compressed_psum` (tests, the
    pretraining all-reduce)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map
    return shard_map


def shard_mapped_psum(fn, mesh, in_specs, out_specs):
    """``shard_map``-wrap ``fn`` (which calls :func:`compressed_psum`
    internally) over ``mesh`` — convenience wrapper for callers of the
    compressed all-reduce (currently the substrate tests; a data-parallel
    training loop would enter here)."""
    return get_shard_map()(fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs)


def compress_tree(grads: Any) -> Any:
    """int8-encode every leaf (block absmax)."""
    return jax.tree.map(lambda g: dict(zip(("q", "s"), _q8(g))), grads)


def decompress_tree(comp: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda c, g: _dq8(c["q"], c["s"], g.shape), comp, like,
        is_leaf=lambda l: isinstance(l, dict) and set(l) == {"q", "s"})


def compressed_psum(grads: Any, axis_name: str, residual: Optional[Any] = None
                    ) -> Tuple[Any, Any]:
    """shard_map-compatible compressed all-reduce with error feedback.

    Returns (mean-reduced grads, new residual). Call inside shard_map with
    ``axis_name`` bound; outside shard_map it degrades to identity psum.
    """
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)
    g_eff = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads,
                         residual)
    comp = compress_tree(g_eff)
    deq = decompress_tree(comp, g_eff)
    new_residual = jax.tree.map(lambda g, d: g - d, g_eff, deq)
    reduced = jax.tree.map(lambda d: jax.lax.pmean(d, axis_name), deq)
    return reduced, new_residual


def compression_error(g: jax.Array) -> float:
    """Relative L2 error of one int8 round-trip (for tests/benchmarks)."""
    q, s = _q8(g)
    d = _dq8(q, s, g.shape)
    return float(jnp.linalg.norm(g - d) / (jnp.linalg.norm(g) + 1e-12))
