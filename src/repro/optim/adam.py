"""AdamW in pure JAX, pytree-generic.

Used by (a) the PTQ reconstruction engine (paper: "We use the Adam optimizer
for all methods and models") and (b) the pretraining loop.

Distributed-memory feature: ``moment_dtype='int8'`` stores both Adam moments
block-quantized to int8 (128-element blocks, absmax scales) — an application
of the paper's own theme to optimizer state, halving-to-quartering optimizer
HBM at 1000-node scale. Dequantize→update→requantize happens inside the jitted
step so the fp32 moments are transient.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = None
    moment_dtype: str = "float32"  # float32 | bfloat16 | int8


# ---------------------------------------------------------------- int8 moments
def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Block-wise absmax int8 quantization of a flat-viewable array."""
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def _encode_moment(x: jax.Array, dtype: str, second: bool = False):
    if dtype == "int8":
        # second moment is non-negative with huge dynamic range: store in
        # sqrt domain so small-v blocks don't snap to 0 (which would blow up
        # the m/sqrt(v) update)
        q, s = _q8(jnp.sqrt(x) if second else x)
        return {"q": q, "s": s}
    return x.astype(jnp.dtype(dtype))


def _decode_moment(m: Any, dtype: str, shape, second: bool = False) -> jax.Array:
    if dtype == "int8":
        d = _dq8(m["q"], m["s"], shape)
        return jnp.square(d) if second else d
    return m.astype(jnp.float32)


# ----------------------------------------------------------------------- adam
def adam_init(params: Any, cfg: AdamConfig) -> Any:
    def one(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return {"m": _encode_moment(z, cfg.moment_dtype),
                "v": _encode_moment(z, cfg.moment_dtype, second=True)}
    return {"mu": jax.tree.map(one, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.float32(0.0)


def adam_update(grads: Any, state: Any, params: Any, cfg: AdamConfig,
                lr_scale: Any = 1.0) -> Tuple[Any, Any, jax.Array]:
    """Returns (new_params, new_state, grad_norm).

    ``lr_scale`` is either a scalar (python number or traced array) applied
    uniformly, or a pytree matching ``params`` whose leaves scale ``cfg.lr``
    per leaf. The pytree form lets heterogeneous learning rates (e.g. the PTQ
    engine's per-site lr rules) ride one tree-wide update instead of a Python
    loop of per-group calls.
    """
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * clip, grads)

    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def one(g, p, mu, scale):
        lr = cfg.lr * scale
        g32 = g.astype(jnp.float32)
        m = _decode_moment(mu["m"], cfg.moment_dtype, p.shape)
        v = _decode_moment(mu["v"], cfg.moment_dtype, p.shape, second=True)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p32
        newp = (p32 - lr * upd).astype(p.dtype)
        return newp, {"m": _encode_moment(m, cfg.moment_dtype),
                      "v": _encode_moment(v, cfg.moment_dtype, second=True)}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    if isinstance(lr_scale, (int, float, jax.Array)):
        flat_s = [lr_scale] * len(flat_p)
    else:
        flat_s = treedef.flatten_up_to(lr_scale)
    out = [one(g, p, mu, s)
           for g, p, mu, s in zip(flat_g, flat_p, flat_mu, flat_s)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}, gnorm
