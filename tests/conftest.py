"""Shared tier-1 fixtures.

``no_retrace`` promotes the benchmark-only compile-count assertion into the
test suite: it yields the ``repro.analysis.no_retrace`` guard, so a test can
warm a compiled path and then demand compile flatness:

    def test_something_stays_compiled(no_retrace):
        warm()                      # first call compiles
        with no_retrace(0):
            warm()                  # any engine retrace fails the test
"""
import pytest


@pytest.fixture
def no_retrace():
    from repro.analysis import no_retrace as guard
    return guard
