"""Telemetry tier-1 suite (repro.obs): span nesting/attribution, the
disabled-mode no-op fast path, exact-sample histogram percentiles, manifest
round-trips (bench JSON rows + PTQ checkpoint meta), JSONL sink validation,
compile attribution, and the zero-compile contract for instrumented warm
paths (telemetry is host-side only, so a warmed jit under live spans must
never trace or compile again)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.run import rows_to_records, stamp_records
from repro.obs import compile_events
from repro.obs.serve_metrics import ServeMetrics, percentiles_from_events
from repro.obs.sink import (SCHEMA_VERSION, JsonlSink, ListSink, RunManifest,
                            check_bench, current_manifest, validate_events)
from repro.obs.telemetry import (_NULL_SPAN, TELEMETRY, Histogram, Stopwatch,
                                 now)


# ----------------------------------------------------------------- disabled
def test_disabled_span_is_shared_noop():
    """Disabled telemetry hands out one shared inert span — no allocation,
    no clock read, nothing recorded — so instrumented hot loops never
    branch on ``enabled``."""
    assert not TELEMETRY.enabled
    sp = TELEMETRY.span("obs.test.disabled", idx=3)
    assert sp is TELEMETRY.span("obs.test.other") is _NULL_SPAN
    with sp as s:
        s.annotate(x=1)
        s.block_on(jnp.zeros(2))
    assert "span.obs.test.disabled" not in TELEMETRY.histograms
    assert TELEMETRY.current_span() is None


# -------------------------------------------------------------------- spans
def test_span_nesting_and_attribution():
    """Nested spans record parent/depth, merged annotations, and land in
    the sink schema-stamped; the enclosing scope restores disabled state."""
    sink = ListSink()
    with TELEMETRY.enabled_scope(sink=sink):
        with TELEMETRY.span("obs.test.outer", stage="a") as so:
            so.annotate(blocks=2)
            assert TELEMETRY.current_span() == "obs.test.outer"
            with TELEMETRY.span("obs.test.inner"):
                assert TELEMETRY.current_span() == "obs.test.inner"
    assert not TELEMETRY.enabled
    inner, outer = [r for r in sink.records if r["kind"] == "span"]
    assert inner["name"] == "obs.test.inner"
    assert inner["parent"] == "obs.test.outer" and inner["depth"] == 1
    assert outer["name"] == "obs.test.outer"
    assert outer["parent"] is None and outer["depth"] == 0
    assert outer["attrs"] == {"stage": "a", "blocks": 2}
    assert outer["dur_us"] >= inner["dur_us"] >= 0.0
    assert all(r["schema"] == SCHEMA_VERSION and "ts" in r
               for r in (inner, outer))
    # span durations also feed the process-global timing histograms
    assert TELEMETRY.histograms["span.obs.test.outer"].count >= 1


def test_span_sync_folds_device_time():
    """``block_on`` registers device values whose completion belongs to the
    span (block_until_ready at exit), recorded as ``synced``."""
    sink = ListSink()
    x = jnp.arange(4.0)
    with TELEMETRY.enabled_scope(sink=sink):
        with TELEMETRY.span("obs.test.sync") as sp:
            sp.block_on(x * 2.0)
    (rec,) = [r for r in sink.records if r["kind"] == "span"]
    assert rec["synced"] is True and rec["dur_us"] > 0.0


def test_stopwatch_and_now_monotonic():
    sw = Stopwatch()
    t0 = now()
    assert sw.elapsed_s() >= 0.0 and now() >= t0
    sw.restart()
    assert sw.elapsed_us() >= 0.0


# --------------------------------------------------------------- histograms
def test_histogram_percentiles_linear_interp():
    """Percentiles match numpy's default linear interpolation over the
    retained samples; the empty histogram summarizes to zeros."""
    h = Histogram("obs.test.h")
    for v in range(1, 101):
        h.observe(float(v))
    data = np.arange(1, 101, dtype=np.float64)
    assert h.percentile(50) == pytest.approx(np.percentile(data, 50))
    assert h.percentile(95) == pytest.approx(np.percentile(data, 95))
    s = h.summary()
    assert s["count"] == 100 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert Histogram("obs.test.empty").summary() == {
        "count": 0.0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0}


def test_snapshot_collects_all_registries():
    TELEMETRY.counter("obs.test.ctr").inc(3)
    TELEMETRY.gauge("obs.test.g").set(2.5)
    TELEMETRY.histogram("obs.test.snap").observe(10.0)
    snap = TELEMETRY.snapshot()
    assert snap["counters"]["obs.test.ctr"] == 3
    assert snap["gauges"]["obs.test.g"] == 2.5
    assert snap["histograms"]["obs.test.snap"]["count"] == 1


# ----------------------------------------------------------------- manifest
def test_manifest_roundtrip_and_brief():
    m = current_manifest()
    assert m is current_manifest()  # process-cached
    assert m.schema_version == SCHEMA_VERSION and m.git_sha
    # unknown fields from a newer writer are dropped on the way back in
    assert RunManifest.from_dict(dict(m.to_dict(), extra="ignored")) == m
    assert set(m.brief()) == {"git_sha", "schema_version"}


def test_bench_records_manifest_stamped(tmp_path):
    """``benchmarks.run --json`` rows round-trip through the CSV parser and
    come out manifest-stamped; ``check_bench`` flags a missing stamp."""
    rows = ["recon/smoke,12.5,steps_per_s=80.0;compile_count=2",
            "serve/requests/int8-kv,9000.0,requests=10;slots=4"]
    records = stamp_records(rows_to_records(rows))
    assert records[0]["steps_per_s"] == 80.0
    for rec in records:
        assert rec["manifest"]["git_sha"] == current_manifest().git_sha
        assert rec["manifest"]["schema_version"] == SCHEMA_VERSION
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(records))
    assert check_bench(str(p)) == []
    records[1].pop("manifest")
    p.write_text(json.dumps(records))
    assert any("no manifest stamp" in e for e in check_bench(str(p)))


def test_ptq_checkpoint_meta_carries_manifest(tmp_path):
    """PTQ checkpoint meta records which code/runtime produced the partial
    state — readable back as a RunManifest."""
    from repro.checkpoint.checkpoint import PTQCheckpointer, load_pytree
    ck = PTQCheckpointer(str(tmp_path))
    ck.save(next_block=1, finalized=[{"w": jnp.ones((2, 2))}], astates={},
            reports=[], x_fp=jnp.zeros((2,)), x_q=jnp.zeros((2,)))
    _, meta = load_pytree(ck.path)
    m = RunManifest.from_dict(meta["manifest"])
    assert m.git_sha == current_manifest().git_sha
    assert m.schema_version == SCHEMA_VERSION


# --------------------------------------------------------------- JSONL sink
def test_jsonl_sink_schema_valid(tmp_path):
    """A real run's event file opens with the manifest, every record is
    kind-tagged and schema-stamped, and the validator refuses a record
    written by a newer schema."""
    path = str(tmp_path / "events.jsonl")
    sink = JsonlSink(path)
    with TELEMETRY.enabled_scope(sink=sink, manifest=current_manifest()):
        with TELEMETRY.span("obs.test.run"):
            TELEMETRY.emit({"kind": "allocation", "digest": "abc"})
    sink.close()
    assert validate_events(path) == []
    with open(path) as fh:
        kinds = [json.loads(line)["kind"] for line in fh]
    assert kinds[0] == "manifest"
    assert "span" in kinds and "allocation" in kinds
    with open(path, "a") as fh:
        fh.write(json.dumps({"kind": "x", "schema": SCHEMA_VERSION + 1})
                 + "\n")
    assert any("newer than this reader" in e for e in validate_events(path))
    assert validate_events(str(tmp_path / "missing.jsonl"))  # unreadable


# ------------------------------------------------------------ serve metrics
def test_serve_metrics_request_lifecycle_event():
    """submit -> admitted closes the queue-wait and TTFT windows and emits
    one ``kind="request"`` sink event; direct engine admits (no submit
    stamp) have no queue to time; the bench folds events back into
    percentiles with ``percentiles_from_events``."""
    sink = ListSink()
    m = ServeMetrics()
    with TELEMETRY.enabled_scope(sink=sink):
        m.on_submit(7)
        t = now()
        m.on_admitted(7, bucket=8, admit_start=t, first_token_t=t + 2e-3)
    (req,) = [r for r in sink.records if r["kind"] == "request"]
    assert req["rid"] == 7 and req["bucket"] == 8
    assert req["ttft_us"] >= req["queue_wait_us"] >= 0.0
    s = m.request_summary()
    assert s["admitted"] == 1 and s["ttft_us"]["count"] == 1
    m.on_admitted(8, bucket=8, admit_start=t, first_token_t=t)
    assert m.ttft_us.count == 1  # direct admit: untimed, not mis-timed
    folded = percentiles_from_events(sink.records, "request", "ttft_us")
    assert folded["count"] == 1 and folded["p50"] == req["ttft_us"]
    assert percentiles_from_events([], "request", "ttft_us") is None


# --------------------------------------------------- compiles & zero-retrace
def test_compile_attribution_and_zero_compile_warm_path(no_retrace):
    """Backend compiles are attributed to the innermost open span; once a
    function is warm, running it *under live telemetry* (spans + sink +
    block_on) adds zero traces and zero backend compiles — the host-side
    only contract that keeps recon-chunk and serve-decode jaxprs identical
    with telemetry on or off."""
    installed = compile_events.install()
    assert compile_events.install() == installed  # idempotent

    @jax.jit
    def f(x):
        return x * 2.0 + 1.0

    x = jnp.arange(8, dtype=jnp.float32)
    sink = ListSink()
    with TELEMETRY.enabled_scope(sink=sink):
        with TELEMETRY.span("obs.test.compile"):
            np.asarray(f(x))  # cold call: compiles inside the span
    if installed:
        assert compile_events.compiles_by_span().get(
            "obs.test.compile", 0) >= 1
        assert any(r["kind"] == "compile"
                   and r["span"] == "obs.test.compile"
                   for r in sink.records)
    warm_sink = ListSink()
    with TELEMETRY.enabled_scope(sink=warm_sink):
        with no_retrace(0, xla_budget=0):
            for i in range(3):
                with TELEMETRY.span("obs.test.warm", i=i) as sp:
                    sp.block_on(f(x))
    assert sum(1 for r in warm_sink.records if r["kind"] == "span") == 3
