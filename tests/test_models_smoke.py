"""Per-architecture smoke tests (reduced configs): forward + train-style loss
step on CPU, asserting output shapes and no NaNs; plus prefill/decode
consistency for every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.context import QuantCtx
from repro.models import build_model

B, S = 2, 32


def _batch(cfg, key=jax.random.key(0)):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k3, (B, S, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            k3, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch, QuantCtx(mode="fp"))
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # a training signal exists: some gradient is nonzero
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0.0, f"{arch}: zero gradients"
    # one SGD step keeps loss finite
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = model.loss(new_params, batch, QuantCtx(mode="fp"))
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_matches_forward(arch):
    """prefill(t[:-1]) + decode_step(t[-1]) must agree with full forward."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg, jax.random.key(2))
    tokens = batch["tokens"]
    ctx = QuantCtx(mode="fp")

    if cfg.family == "encdec":
        cache = model.init_cache(B, S + 4, enc_len=S)
        _, cache = model.prefill(params, tokens[:, :-1], batch["frames"],
                                 cache, ctx)
        logits, _ = model.decode_step(params, tokens[:, -1:], cache,
                                      jnp.int32(S - 1), ctx)
        enc_out = model.encode(params, batch["frames"], ctx)
        x_full, _ = model.decode_full(params, tokens, enc_out, ctx)
        ref = x_full[:, -1:] @ params["lm_head"].astype(x_full.dtype)
    elif cfg.family == "vlm":
        P = cfg.n_patches
        cache = model.init_cache(B, P + S + 4)
        _, cache = model.prefill(params, tokens[:, :-1], cache, ctx,
                                 extra_embeds=batch["patch_embeds"])
        logits, _ = model.decode_step(params, tokens[:, -1:], cache,
                                      jnp.int32(P + S - 1), ctx)
        x, _, _ = model.backbone(params, tokens, ctx,
                                 extra_embeds=batch["patch_embeds"])
        ref = (x[:, -1:] @ model.lm_head(params).astype(x.dtype)
               ) * cfg.logit_mult
    else:
        cache = model.init_cache(B, S + 4)
        _, cache = model.prefill(params, tokens[:, :-1], cache, ctx)
        logits, _ = model.decode_step(params, tokens[:, -1:], cache,
                                      jnp.int32(S - 1), ctx)
        if cfg.family == "ssm":
            x = model.backbone(params, tokens, ctx)
            ref = x[:, -1:] @ params["lm_head"].astype(x.dtype)
        elif cfg.family == "hybrid":
            x, _ = model.backbone(params, tokens, ctx)
            ref = x[:, -1:] @ params["lm_head"].astype(x.dtype)
        else:
            x, _, _ = model.backbone(params, tokens, ctx)
            ref = (x[:, -1:] @ model.lm_head(params).astype(x.dtype)
                   ) * cfg.logit_mult

    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-130m",
                                  "recurrentgemma-2b", "deepseek-v3-671b"])
def test_multi_step_decode_consistency(arch):
    """Greedy-decode N tokens stepwise == teacher-forced forward argmax."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    tokens = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab)
    ctx = QuantCtx(mode="fp")
    n_extra = 4

    cache = model.init_cache(B, S + n_extra)
    _, cache = model.prefill(params, tokens, cache, ctx)
    cur = tokens[:, -1:]
    last_logits = None
    for t in range(n_extra):
        last_logits, cache = model.decode_step(
            params, cur, cache, jnp.int32(S + t), ctx)
        nxt = jnp.argmax(last_logits[:, -1], axis=-1)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        cur = nxt
    assert tokens.shape == (B, S + n_extra)
    assert np.isfinite(np.asarray(last_logits, np.float32)).all()
