"""Scan-fused reconstruction engine: recorded-trajectory parity + caching.

The engine must be a pure execution-model change over the seed per-iteration
loop: same RNG stream, same per-step math. The original ``--legacy-loop``
oracle is gone; its trajectories for a fixed set of recipes/blocks/keys were
recorded to ``tests/fixtures/recon_legacy_trajectories.npz`` before removal
(see ``tests/fixtures/record_fixtures.py``) and the scanned engine is pinned
against that fixture here. The compiled-step cache must make L structurally
identical blocks compile the step/teacher/student/recon_error exactly once.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantRecipe
from repro.core import reconstruct as rec
from repro.core.context import QuantCtx
from repro.core.reconstruct import (BlockHandle, Site, quantize_blocks,
                                    reconstruct_block)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "recon_legacy_trajectories.npz")
# Recorded on the same step math but a different compiled program; the
# original in-process scan-vs-legacy parity held at rtol=2e-4, widened here
# for cross-platform/jax-version float drift.
RTOL, ATOL = 1e-3, 1e-5


def flatten_tree(prefix, tree):
    """Pytree -> {"prefix/<path>": np.ndarray}; must stay in sync with the
    copy in tests/fixtures/record_fixtures.py."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        toks = []
        for p in path:
            if hasattr(p, "key"):
                toks.append(str(p.key))
            elif hasattr(p, "idx"):
                toks.append(f"[{p.idx}]")
            else:
                toks.append(str(p))
        out[prefix + "/" + "|".join(toks)] = np.asarray(leaf)
    return out


@pytest.fixture(scope="module")
def recorded():
    return dict(np.load(FIXTURE))


def assert_matches_fixture(recorded, prefix, tree, msg=""):
    got = flatten_tree(prefix, tree)
    want = {k: v for k, v in recorded.items() if k.startswith(prefix + "/")}
    assert got.keys() == want.keys(), (
        f"{msg}: fixture/state key mismatch under {prefix}: "
        f"only-got={sorted(got.keys() - want.keys())} "
        f"only-recorded={sorted(want.keys() - got.keys())}")
    for k in sorted(want):
        np.testing.assert_allclose(got[k], want[k], rtol=RTOL, atol=ATOL,
                                   err_msg=f"{msg}: {k}")


def make_block(key, name, d=24, h=40, token=None):
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (d, h), jnp.float32) * d**-0.5,
        "w2": jax.random.normal(k2, (h, d), jnp.float32) * h**-0.5,
    }

    def apply(p, x, ctx, _n=name):
        z = jax.nn.gelu(ctx.linear(f"{_n}.w1", x, p["w1"]))
        return ctx.linear(f"{_n}.w2", z, p["w2"]) + x

    sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
    return BlockHandle(name, params, apply, sites, apply_key=token)


def make_chain(n, token, d=24, h=40):
    keys = jax.random.split(jax.random.key(3), n)
    return [make_block(k, f"layers.{i}", d=d, h=h, token=token)
            for i, k in enumerate(keys)]


def _run_single(recipe, block_key, x_key, n, seed=3):
    block = make_block(jax.random.key(block_key), "layers.0")
    x = jax.random.normal(jax.random.key(x_key), (n, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    return reconstruct_block(block, recipe, x, y, jax.random.key(seed))


def _check_single(recorded, tag, recipe, block_key, x_key, n):
    ws, as_, rep = _run_single(recipe, block_key, x_key, n)
    assert_matches_fixture(recorded, f"{tag}/wstates", ws, msg=tag)
    assert_matches_fixture(recorded, f"{tag}/astates", as_, msg=tag)
    np.testing.assert_allclose(
        [rep.err_before, rep.err_after], recorded[f"{tag}/err"],
        rtol=2e-3, err_msg=f"{tag}: err")
    np.testing.assert_allclose(np.asarray(rep.loss_curve),
                               recorded[f"{tag}/loss_curve"],
                               rtol=2e-3, atol=ATOL, err_msg=f"{tag}: loss")
    np.testing.assert_allclose(np.asarray(rep.mse_curve),
                               recorded[f"{tag}/mse_curve"],
                               rtol=2e-3, atol=ATOL, err_msg=f"{tag}: mse")


def test_matches_recorded_legacy_block_w4a8_qdrop(recorded):
    """Full-path RNG parity vs the recorded per-iteration loop: LSQ
    co-training + QDrop key stream (per-site salt folding must reproduce the
    legacy crc32 constants)."""
    _check_single(
        recorded, "block_w4a8_qdrop",
        QuantRecipe(method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
                    setting="qdrop", iters=50, lr=3e-3, batch_size=8),
        block_key=7, x_key=8, n=48)


def test_matches_recorded_legacy_adaround_regularizer(recorded):
    """The annealed AdaRound regularizer consumes the traced step index
    inside the scan — the trajectory must still match the recording."""
    _check_single(
        recorded, "adaround_reg",
        QuantRecipe(method="adaround", w_bits=4, w_symmetric=True,
                    a_bits=None, iters=40, lr=3e-3, batch_size=8),
        block_key=9, x_key=10, n=32)


def test_matches_recorded_legacy_full_batch(recorded):
    """bs == n skips the choice+take gather; RNG consumption must still
    line up with the recorded loop."""
    _check_single(
        recorded, "full_batch",
        QuantRecipe(method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
                    iters=30, lr=3e-3, batch_size=32),
        block_key=11, x_key=12, n=32)


def test_matches_recorded_legacy_chain_mixed_rules(recorded):
    """Chain parity under mixed-precision rules (per-site bits, lr and
    a_bits=none overrides resolve through the canonicalized plans)."""
    recipe = QuantRecipe(
        method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
        setting="qdrop", iters=30, lr=3e-3, batch_size=8,
        rules=("layers.0.*:w_bits=8,lr=1e-3",
               "layers.2.w2:a_bits=none,method=adaround"))
    x = jax.random.normal(jax.random.key(1), (40, 24), jnp.float32)
    fin, ast, _ = quantize_blocks(make_chain(3, token=None), recipe, x,
                                  as_qtensor=False)
    assert_matches_fixture(recorded, "chain_mixed/finalized", fin,
                           msg="chain_mixed")
    assert_matches_fixture(recorded, "chain_mixed/astates", ast,
                           msg="chain_mixed")


def test_matches_recorded_legacy_layerwise(recorded):
    """recon='layer': per-site sub-blocks (single capture pass) must
    reproduce the recorded per-site trajectories."""
    recipe = QuantRecipe(method="flexround", w_bits=3, w_symmetric=True,
                         a_bits=None, recon="layer", iters=40, lr=3e-3,
                         batch_size=8)
    x = jax.random.normal(jax.random.key(2), (40, 24), jnp.float32)
    fin, _, reports = quantize_blocks(make_chain(2, token=None), recipe, x,
                                      as_qtensor=False)
    assert len(reports) == 4  # one per site
    assert_matches_fixture(recorded, "layerwise/finalized", fin,
                           msg="layerwise")


def test_step_compiles_once_across_same_shape_blocks():
    """>=3 structurally identical blocks sharing an apply_key must compile
    the recon step, teacher, student and recon_error exactly once."""
    token = (object(),)
    blocks = make_chain(4, token=token)
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=40, lr=3e-3, batch_size=8)
    x = jax.random.normal(jax.random.key(4), (32, 24), jnp.float32)
    rec.reset_engine_stats()
    rec.clear_engine_cache()
    quantize_blocks(blocks, recipe, x, chunk=40)
    st = rec.engine_stats()
    assert st.engine_builds == 1
    assert st.engine_hits == len(blocks) * 2 - 1  # teacher + recon reuse
    assert st.step_compiles == 1, st
    assert st.teacher_compiles == 1, st
    assert st.student_compiles == 1, st
    assert st.recon_error_compiles == 1, st
    assert st.schedule_compiles == 1, st
    assert st.probe_compiles == 0, st


def test_compile_count_flat_as_block_count_grows():
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=20, lr=3e-3, batch_size=8)
    x = jax.random.normal(jax.random.key(5), (32, 24), jnp.float32)
    counts = {}
    for n in (2, 4):
        rec.reset_engine_stats()
        rec.clear_engine_cache()
        quantize_blocks(make_chain(n, token=(object(),)), recipe, x,
                        chunk=20)
        counts[n] = rec.engine_stats().compile_count
    assert counts[2] == counts[4], counts


def test_dealias_gives_unique_buffers():
    """Aliased init buffers (constant-dedup) must come out of _dealias as
    distinct buffers so donate_argnums is safe."""
    z = jnp.zeros((4, 4), jnp.float32)
    (tree,) = rec._dealias({"a": {"zero": z}, "b": {"zero": z}})
    la, lb = tree["a"]["zero"], tree["b"]["zero"]
    assert la is not z and lb is not z and la is not lb
    ptr = lambda x: x.unsafe_buffer_pointer()  # noqa: E731
    assert ptr(la) != ptr(lb)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(z))


def test_report_carries_trajectories():
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=25, lr=3e-3, batch_size=8)
    block = make_block(jax.random.key(6), "layers.0")
    x = jax.random.normal(jax.random.key(7), (32, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    _, _, rep = reconstruct_block(block, recipe, x, y, jax.random.key(0))
    assert rep.engine == "scan"
    assert rep.steps_per_s > 0
    assert rep.loss_curve.shape == (recipe.iters,)
    assert rep.mse_curve.shape == (recipe.iters,)
    # trajectories are real fields now: serialization must not drop them
    assert "loss_curve" in dataclasses.asdict(rep)


def test_report_serialization_roundtrips_curves():
    """to_json/from_json (the checkpoint meta path) must round-trip the
    loss/mse trajectories through actual JSON, tolerate unknown keys from a
    newer writer, and default missing curves to empty."""
    import json

    from repro.core.reconstruct import BlockReport

    rep = rec.BlockReport("layers.3", 0.5, 0.1, iters=4, seconds=1.0,
                          steps_per_s=4.0,
                          loss_curve=jnp.asarray([4.0, 3.0, 2.0, 1.0]),
                          mse_curve=jnp.asarray([0.4, 0.3, 0.2, 0.1]))
    doc = json.loads(json.dumps(rep.to_json()))  # must be JSON-safe
    back = BlockReport.from_json(doc)
    assert back.name == rep.name and back.iters == rep.iters
    np.testing.assert_allclose(back.loss_curve,
                               np.asarray(rep.loss_curve), rtol=1e-6)
    np.testing.assert_allclose(back.mse_curve,
                               np.asarray(rep.mse_curve), rtol=1e-6)
    # schema drift: unknown keys dropped, missing curves -> empty defaults
    old = {"name": "b", "err_before": 1.0, "err_after": 0.5, "iters": 2,
           "seconds": 0.1, "from_the_future": True}
    legacy = BlockReport.from_json(old)
    assert legacy.loss_curve.shape == (0,) and legacy.mse_curve.shape == (0,)


def test_report_roundtrips_through_ptq_checkpoint(tmp_path):
    """A resumed run must see the same trajectories the original wrote."""
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=10, batch_size=4)
    x = jax.random.normal(jax.random.key(1), (16, 24), jnp.float32)
    blocks = make_chain(1, token=None)
    _, _, reports = quantize_blocks(blocks, recipe, x,
                                    checkpoint_dir=str(tmp_path))
    from repro.checkpoint.checkpoint import PTQCheckpointer
    resumed = PTQCheckpointer(str(tmp_path)).load(blocks, recipe)
    assert resumed is not None
    loaded = resumed[3]
    assert len(loaded) == len(reports) == 1
    np.testing.assert_allclose(np.asarray(loaded[0].loss_curve),
                               np.asarray(reports[0].loss_curve), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(loaded[0].mse_curve),
                               np.asarray(reports[0].mse_curve), rtol=1e-6)


def test_zero_iters():
    """iters=0 measures init-only recon error: no steps, empty curves."""
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=0, batch_size=4)
    block = make_block(jax.random.key(0), "layers.0")
    x = jax.random.normal(jax.random.key(1), (16, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    _, _, rep = reconstruct_block(block, recipe, x, y, jax.random.key(2))
    assert rep.loss_curve.shape == (0,)
    np.testing.assert_allclose(rep.err_before, rep.err_after, rtol=1e-5)


def test_engine_cache_released_after_quantize_blocks():
    """Engines built inside a quantize_blocks call must not outlive it —
    their closures pin per-call constants (rope tables, encoder output)."""
    rec.clear_engine_cache()
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=5, batch_size=4)
    x = jax.random.normal(jax.random.key(1), (16, 24), jnp.float32)
    quantize_blocks(make_chain(2, token=(object(),)), recipe, x)
    assert len(rec._ENGINE_CACHE) == 0
    # direct reconstruct_block use keeps the bounded-LRU behavior
    block = make_block(jax.random.key(0), "layers.9")
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    reconstruct_block(block, recipe, x, y, jax.random.key(2))
    assert len(rec._ENGINE_CACHE) == 1


def test_engine_scope_evicts_probe_built_engines():
    """engine_scope (the probe-mode entry's lifetime guard) must release
    entries built inside it and leave pre-existing ones alone."""
    rec.clear_engine_cache()
    recipe = QuantRecipe(method="rtn", w_bits=8, a_bits=None, iters=1,
                         batch_size=4)
    x = jax.random.normal(jax.random.key(1), (8, 24), jnp.float32)
    outer = make_block(jax.random.key(0), "layers.0")
    y = outer.apply(outer.params, x, QuantCtx(mode="fp"))
    reconstruct_block(outer, recipe, x, y, jax.random.key(2))
    assert len(rec._ENGINE_CACHE) == 1
    with rec.engine_scope():
        inner = make_block(jax.random.key(5), "layers.1",
                           token=(object(),))
        rec.probe_teacher(inner, recipe)(inner.params, x)
        assert len(rec._ENGINE_CACHE) == 2
    assert len(rec._ENGINE_CACHE) == 1


def test_reconstruct_compile_flat_under_no_retrace(no_retrace):
    """The tier-1 ``no_retrace`` fixture guards the engine cache directly: a
    second structurally identical block (shared apply_key) reconstructs with
    zero new engine compiles, and the guard raises on a cache-defeating
    block."""
    from repro.analysis import RetraceError

    token = "no-retrace-fixture"
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=3, batch_size=4)
    x = jax.random.normal(jax.random.key(60), (4, 24), jnp.float32)
    y = jax.random.normal(jax.random.key(61), (4, 24), jnp.float32)
    reconstruct_block(make_block(jax.random.key(62), "nr0", token=token),
                      recipe, x, y, jax.random.key(0))  # warm
    with no_retrace(0):
        reconstruct_block(make_block(jax.random.key(63), "nr1", token=token),
                          recipe, x, y, jax.random.key(0))
    with pytest.raises(RetraceError):
        with no_retrace(0):
            reconstruct_block(
                make_block(jax.random.key(64), "nr2", token=None),
                recipe, x, y, jax.random.key(0))
