"""Scan-fused reconstruction engine: parity with the legacy loop + caching.

The scanned engine must be a pure execution-model change: same RNG stream,
same per-step math, so final rounding/LSQ states and recon errors match the
seed Python-loop trajectory allclose. The compiled-step cache must make L
structurally identical blocks compile the step/teacher/student/recon_error
exactly once.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantRecipe
from repro.core import reconstruct as rec
from repro.core.context import QuantCtx
from repro.core.reconstruct import (BlockHandle, Site, quantize_blocks,
                                    reconstruct_block)


def make_block(key, name, d=24, h=40, token=None):
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (d, h), jnp.float32) * d**-0.5,
        "w2": jax.random.normal(k2, (h, d), jnp.float32) * h**-0.5,
    }

    def apply(p, x, ctx, _n=name):
        z = jax.nn.gelu(ctx.linear(f"{_n}.w1", x, p["w1"]))
        return ctx.linear(f"{_n}.w2", z, p["w2"]) + x

    sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
    return BlockHandle(name, params, apply, sites, apply_key=token)


def make_chain(n, token, d=24, h=40):
    keys = jax.random.split(jax.random.key(3), n)
    return [make_block(k, f"layers.{i}", d=d, h=h, token=token)
            for i, k in enumerate(keys)]


def assert_trees_close(a, b, rtol=2e-4, atol=1e-6, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f"{msg}: leaf count {len(la)} != {len(lb)}"
    assert jax.tree.structure(a) == jax.tree.structure(b), msg
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol,
                                   err_msg=f"{msg} leaf {i}")


def _both_engines(recipe, block, x, y, seed=3):
    outs = {}
    for engine in ("legacy", "scan"):
        outs[engine] = reconstruct_block(block, recipe, x, y,
                                         jax.random.key(seed), engine=engine)
    return outs["legacy"], outs["scan"]


def test_scan_matches_legacy_block_w4a8_qdrop():
    """Block-mode parity under the full path: LSQ co-training + QDrop RNG
    (the scanned engine folds per-site salts instead of crc32 constants)."""
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, setting="qdrop", iters=50, lr=3e-3,
                         batch_size=8)
    block = make_block(jax.random.key(7), "layers.0")
    x = jax.random.normal(jax.random.key(8), (48, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    (ws_l, as_l, rep_l), (ws_s, as_s, rep_s) = _both_engines(recipe, block, x, y)
    assert_trees_close(ws_l, ws_s, msg="wstates")
    assert_trees_close(as_l, as_s, msg="astates")
    np.testing.assert_allclose(rep_l.err_after, rep_s.err_after, rtol=1e-3)
    np.testing.assert_allclose(rep_l.err_before, rep_s.err_before, rtol=1e-4)


def test_scan_matches_legacy_adaround_regularizer():
    """The annealed AdaRound regularizer consumes the traced step index
    inside the scan — trajectories must still match."""
    recipe = QuantRecipe(method="adaround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=40, lr=3e-3, batch_size=8)
    block = make_block(jax.random.key(9), "layers.0")
    x = jax.random.normal(jax.random.key(10), (32, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    (ws_l, _, rep_l), (ws_s, _, rep_s) = _both_engines(recipe, block, x, y)
    assert_trees_close(ws_l, ws_s, msg="wstates")
    np.testing.assert_allclose(rep_l.err_after, rep_s.err_after, rtol=1e-3)


def test_scan_matches_legacy_full_batch_skips_gather():
    """bs == n: both engines skip the choice+take gather and still agree."""
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=30, lr=3e-3, batch_size=32)
    block = make_block(jax.random.key(11), "layers.0")
    x = jax.random.normal(jax.random.key(12), (32, 24), jnp.float32)  # n == bs
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    (ws_l, as_l, rep_l), (ws_s, as_s, rep_s) = _both_engines(recipe, block, x, y)
    assert_trees_close(ws_l, ws_s, msg="wstates")
    assert_trees_close(as_l, as_s, msg="astates")
    np.testing.assert_allclose(rep_l.err_after, rep_s.err_after, rtol=1e-3)


def test_scan_matches_legacy_chain_mixed_rules():
    """Chain parity under a mixed-precision rule set (per-site bits, lr and
    a_bits=none overrides resolve through the canonicalized plans)."""
    recipe = QuantRecipe(
        method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
        setting="qdrop", iters=30, lr=3e-3, batch_size=8,
        rules=("layers.0.*:w_bits=8,lr=1e-3",
               "layers.2.w2:a_bits=none,method=adaround"))
    x = jax.random.normal(jax.random.key(1), (40, 24), jnp.float32)
    fins, asts = [], []
    for engine in ("legacy", "scan"):
        blocks = make_chain(3, token=None)
        fin, ast, _ = quantize_blocks(blocks, recipe, x, as_qtensor=False,
                                      engine=engine)
        fins.append(fin)
        asts.append(ast)
    assert_trees_close(fins[0], fins[1], msg="finalized")
    assert_trees_close(asts[0], asts[1], msg="astates")


def test_scan_matches_legacy_layerwise():
    """recon='layer': per-site sub-blocks (single capture pass) ride the
    same engines; final dequantized params must agree."""
    recipe = QuantRecipe(method="flexround", w_bits=3, w_symmetric=True,
                         a_bits=None, recon="layer", iters=40, lr=3e-3,
                         batch_size=8)
    x = jax.random.normal(jax.random.key(2), (40, 24), jnp.float32)
    fins = []
    for engine in ("legacy", "scan"):
        blocks = make_chain(2, token=None)
        fin, _, reports = quantize_blocks(blocks, recipe, x, as_qtensor=False,
                                          engine=engine)
        assert len(reports) == 4  # one per site
        fins.append(fin)
    assert_trees_close(fins[0], fins[1], msg="finalized")


def test_step_compiles_once_across_same_shape_blocks():
    """>=3 structurally identical blocks sharing an apply_key must compile
    the recon step, teacher, student and recon_error exactly once."""
    token = (object(),)
    blocks = make_chain(4, token=token)
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=40, lr=3e-3, batch_size=8)
    x = jax.random.normal(jax.random.key(4), (32, 24), jnp.float32)
    rec.reset_engine_stats()
    rec.clear_engine_cache()
    quantize_blocks(blocks, recipe, x, engine="scan", chunk=40)
    st = rec.engine_stats()
    assert st.engine_builds == 1
    assert st.engine_hits == len(blocks) * 2 - 1  # teacher + recon reuse
    assert st.step_compiles == 1, st
    assert st.teacher_compiles == 1, st
    assert st.student_compiles == 1, st
    assert st.recon_error_compiles == 1, st
    assert st.schedule_compiles == 1, st


def test_compile_count_flat_as_block_count_grows():
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=20, lr=3e-3, batch_size=8)
    x = jax.random.normal(jax.random.key(5), (32, 24), jnp.float32)
    counts = {}
    for n in (2, 4):
        rec.reset_engine_stats()
        rec.clear_engine_cache()
        quantize_blocks(make_chain(n, token=(object(),)), recipe, x,
                        engine="scan", chunk=20)
        counts[n] = rec.engine_stats().compile_count
    assert counts[2] == counts[4], counts


def test_dealias_gives_unique_buffers():
    """Aliased init buffers (constant-dedup) must come out of _dealias as
    distinct buffers so donate_argnums is safe."""
    z = jnp.zeros((4, 4), jnp.float32)
    (tree,) = rec._dealias({"a": {"zero": z}, "b": {"zero": z}})
    la, lb = tree["a"]["zero"], tree["b"]["zero"]
    assert la is not z and lb is not z and la is not lb
    ptr = lambda x: x.unsafe_buffer_pointer()  # noqa: E731
    assert ptr(la) != ptr(lb)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(z))


def test_report_carries_engine_and_trajectories():
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=25, lr=3e-3, batch_size=8)
    block = make_block(jax.random.key(6), "layers.0")
    x = jax.random.normal(jax.random.key(7), (32, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    for engine in ("scan", "legacy"):
        _, _, rep = reconstruct_block(block, recipe, x, y, jax.random.key(0),
                                      engine=engine)
        assert rep.engine == engine
        assert rep.steps_per_s > 0
        assert rep.loss_curve.shape == (recipe.iters,)
        assert rep.mse_curve.shape == (recipe.iters,)
        # trajectories are JSON-safe by omission: extra attrs, not fields
        assert "loss_curve" not in dataclasses.asdict(rep)


def test_zero_iters_both_engines():
    """iters=0 measures init-only recon error: no steps, empty curves."""
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=0, batch_size=4)
    block = make_block(jax.random.key(0), "layers.0")
    x = jax.random.normal(jax.random.key(1), (16, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    errs = {}
    for engine in ("scan", "legacy"):
        _, _, rep = reconstruct_block(block, recipe, x, y, jax.random.key(2),
                                      engine=engine)
        assert rep.loss_curve.shape == (0,)
        errs[engine] = (rep.err_before, rep.err_after)
        np.testing.assert_allclose(rep.err_before, rep.err_after, rtol=1e-5)
    np.testing.assert_allclose(errs["scan"], errs["legacy"], rtol=1e-4)


def test_engine_cache_released_after_quantize_blocks():
    """Engines built inside a quantize_blocks call must not outlive it —
    their closures pin per-call constants (rope tables, encoder output)."""
    rec.clear_engine_cache()
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=5, batch_size=4)
    x = jax.random.normal(jax.random.key(1), (16, 24), jnp.float32)
    quantize_blocks(make_chain(2, token=(object(),)), recipe, x,
                    engine="scan")
    assert len(rec._ENGINE_CACHE) == 0
    # direct reconstruct_block use keeps the bounded-LRU behavior
    block = make_block(jax.random.key(0), "layers.9")
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    reconstruct_block(block, recipe, x, y, jax.random.key(2), engine="scan")
    assert len(rec._ENGINE_CACHE) == 1


def test_unknown_engine_rejected():
    recipe = QuantRecipe(method="rtn", w_bits=8, a_bits=None, iters=1,
                         batch_size=4)
    block = make_block(jax.random.key(0), "layers.0")
    x = jax.random.normal(jax.random.key(1), (8, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    with pytest.raises(ValueError, match="engine"):
        reconstruct_block(block, recipe, x, y, jax.random.key(2),
                          engine="vectorized")
    with pytest.raises(ValueError, match="engine"):
        quantize_blocks([block], recipe, x, engine="vectorized")


@pytest.mark.slow
def test_scan_engine_is_much_faster_dispatch_bound():
    """Steady-state throughput on a dispatch-bound chain: the scanned engine
    must beat the per-step loop by a wide margin (benchmarked at >5x; the
    test asserts 3x to stay robust on noisy CI runners)."""
    import statistics

    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=100, lr=3e-3, batch_size=16)
    x = jax.random.normal(jax.random.key(8), (64, 24), jnp.float32)
    med = {}
    for engine in ("scan", "legacy"):
        rec.clear_engine_cache()
        blocks = make_chain(4, token=(object(),))
        _, _, reports = quantize_blocks(blocks, recipe, x, engine=engine)
        med[engine] = statistics.median(r.steps_per_s for r in reports)
    assert med["scan"] >= 3.0 * med["legacy"], med
