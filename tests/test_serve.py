"""Serving-engine tier-1 suite (repro.serve): bucketed-prefill bit parity,
slot recycling, retrace flatness under mixed occupancy, the int8 KV HBM
win, and the machine-readable capability-degradation contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.context import QuantCtx
from repro.core.quant_config import QuantRecipe
from repro.core.reconstruct import quantize_blocks
from repro.data import CalibrationSet, SyntheticTokens
from repro.models import build_model
from repro.serve import (EngineConfig, KVQuantUnsupported, Request,
                         Scheduler, ServeEngine, serve_capability)
from repro.serve import kv as skv

MAX_LEN = 32  # engine buckets: [8, 16, 32]


@pytest.fixture(scope="module")
def deploy_lm():
    """Export-only quantized smoke LM + deploy ctx (shared, read-only)."""
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    recipe = QuantRecipe(method="flexround", w_bits=4, a_bits=8, iters=0,
                         batch_size=4)
    cal = CalibrationSet.build(SyntheticTokens(vocab=cfg.vocab, seq_len=16,
                                               seed=0), 4)
    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)
    finalized, astates, _ = quantize_blocks(blocks, recipe, x0)
    qparams = assemble(finalized)
    ctx = QuantCtx(mode="deploy", recipe=recipe, astates=astates,
                   backend="xla")
    return cfg, model, qparams, ctx


@pytest.fixture(scope="module")
def engine(deploy_lm):
    """Shared 3-slot engine; every test that runs requests drains them, so
    the engine is idle (all slots free) between tests."""
    _, model, qparams, ctx = deploy_lm
    return ServeEngine(model, qparams, ctx,
                       EngineConfig(slots=3, max_len=MAX_LEN,
                                    prefill_group=2, kv_quant=True))


@pytest.fixture(scope="module")
def ref_engine(deploy_lm):
    """Single-slot engine: the isolated-request oracle."""
    _, model, qparams, ctx = deploy_lm
    return ServeEngine(model, qparams, ctx,
                       EngineConfig(slots=1, max_len=MAX_LEN,
                                    prefill_group=1, kv_quant=True))


def _prompts(lens, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in lens]


def _ref_greedy(ref_engine, toks, max_new):
    """Greedy tokens for one request run alone through the 1-slot engine."""
    out = [ref_engine.admit([(0, toks, max_new)])[0][1]]
    while ref_engine.active:
        out.extend(t for _, t in ref_engine.step())
    ref_engine.drain_finished()
    return out


# ------------------------------------------------------------ bucket parity
def test_bucketed_prefill_parity_per_bucket(deploy_lm):
    """Right-padding a prompt to its bucket must not change the last real
    position's result: padded keys are strictly future to every real query
    under the causal mask, so they contribute exactly zero. XLA may still
    tile the softmax reduction differently for the padded key length, so
    the pin is a reduction-order rounding envelope on the hidden state
    plus *identical* greedy tokens (the serving-visible contract)."""
    cfg, model, qparams, ctx = deploy_lm
    for bucket in (8, 16, 32):
        n = bucket - 3
        toks = jax.random.randint(jax.random.key(bucket), (2, n), 0,
                                  cfg.vocab, dtype=jnp.int32)
        cache = model.init_cache(2, n, kv_quant=True)
        last, _ = model.prefill(qparams, toks, cache, ctx)
        padded = jnp.zeros((2, bucket), jnp.int32).at[:, :n].set(toks)
        cache_p = model.init_cache(2, bucket, kv_quant=True)
        last_p, _ = model.prefill(qparams, padded, cache_p, ctx,
                                  true_len=jnp.full((2,), n, jnp.int32))
        a, b = np.asarray(last_p[:, 0]), np.asarray(last[:, -1])
        np.testing.assert_allclose(
            a, b, rtol=1e-5, atol=2e-6,
            err_msg=f"bucket {bucket}: padded prefill left the rounding "
                    "envelope — padding is leaking into real positions")
        head = np.asarray(model.lm_head(qparams), np.float32)
        np.testing.assert_array_equal(
            (a.astype(np.float32) @ head).argmax(-1),
            (b.astype(np.float32) @ head).argmax(-1),
            err_msg=f"bucket {bucket}: greedy token changed under padding")


# ------------------------------------------------------- continuous batching
def test_continuous_batching_matches_isolated_decode(engine, ref_engine,
                                                     deploy_lm):
    """Five requests over three slots (mixed lengths, two buckets, slot
    reuse mid-flight) emit exactly the tokens each request gets alone."""
    cfg = deploy_lm[0]
    lens = [5, 9, 12, 7, 3]
    prompts = _prompts(lens, cfg.vocab, seed=1)
    reqs = [Request(i, p, max_new=6) for i, p in enumerate(prompts)]
    with Scheduler(engine) as sched:
        outs = sched.run(reqs)
    assert engine.active == 0 and not engine._finished
    for i, p in enumerate(prompts):
        assert outs[i] == _ref_greedy(ref_engine, p, 6), f"request {i}"


def test_slot_recycling(engine, ref_engine, deploy_lm):
    """A slot freed by a finished request serves the next request with the
    same tokens as a fresh engine would — stale KV from the previous
    occupant is never visible (the mask reads only positions the current
    occupant has written)."""
    cfg = deploy_lm[0]
    long, short = _prompts([20, 4], cfg.vocab, seed=2)
    first = engine.admit([(100, long, 5)])
    while engine.active:
        first.extend(engine.step())
    engine.drain_finished()
    recycled = engine.admit([(101, short, 5)])
    got = [recycled[0][1]]
    while engine.active:
        got.extend(t for _, t in engine.step())
    engine.drain_finished()
    assert got == _ref_greedy(ref_engine, short, 5)


# ---------------------------------------------------------- retrace flatness
def test_compile_count_flat_across_occupancy(engine, deploy_lm, no_retrace):
    """After __init__ the engine never compiles again: occupancy, group
    fill, request count, and bucket mix all reuse the AOT executables
    (the acceptance gate for continuous batching)."""
    cfg = deploy_lm[0]
    before = engine.compile_count
    assert before == len(engine.buckets) + 1
    lens = [3, 6, 14, 25, 9, 5, 28, 2]  # all three buckets, odd group fills
    reqs = [Request(200 + i, p, max_new=4)
            for i, p in enumerate(_prompts(lens, cfg.vocab, seed=3))]
    with no_retrace(0, xla_budget=0):
        with Scheduler(engine) as sched:
            outs = sched.run(reqs)
    assert engine.compile_count == before
    assert sorted(outs) == [200 + i for i in range(len(lens))]
    assert all(len(v) == 4 for v in outs.values())


# ------------------------------------------------------------------ int8 KV
def test_int8_kv_halves_hbm_per_slot(deploy_lm):
    """The int8 cache must be strictly smaller per slot than the bf16
    cache (scales cost (1/head_dim) extra, codes save half)."""
    _, model, _, _ = deploy_lm
    slots = 4
    c8 = model.init_cache(slots, 64, kv_quant=True)
    cb = model.init_cache(slots, 64, dtype=jnp.bfloat16, kv_quant=False)
    mib8 = skv.hbm_per_slot_mib(c8, slots)
    mibb = skv.hbm_per_slot_mib(cb, slots)
    assert mib8 < mibb, f"int8 {mib8} MiB/slot not below bf16 {mibb}"
    # the bytes accessor is the single source the bench row and memcheck's
    # QL403 both read — it must tile back to the whole cache
    assert skv.hbm_per_slot_bytes(c8, slots) * slots == skv.cache_bytes(c8)


def test_kv_scales_floored_above_subnormal(deploy_lm):
    """Stored KV scales obey the QL303 contract: >= KV_SCALE_MIN even for
    an all-zero append (the absmax floor), far above float32 tiny."""
    _, model, qparams, ctx = deploy_lm
    toks = jnp.zeros((1, 8), jnp.int32)  # degenerate prompt
    cache = model.init_cache(1, 8, kv_quant=True)
    _, cache = model.prefill(qparams, toks, cache, ctx)
    for nm, buf in cache.items():
        if nm.endswith("_scale"):
            lo = float(jnp.min(buf))
            assert lo >= skv.KV_SCALE_MIN, f"{nm} scale {lo} below floor"
    codes, scale = skv.kv_quantize(jnp.zeros((1, 2, 4), jnp.float32))
    assert float(jnp.min(scale)) >= skv.KV_SCALE_MIN
    assert not np.any(np.asarray(codes))


# --------------------------------------------------- capability degradation
def test_kv_quant_named_error_ssm_hybrid():
    """Families without a KV cache raise the machine-readable
    ``KVQuantUnsupported`` (a ValueError), never a bare TypeError."""
    for arch, family in (("mamba2-130m", "ssm"),
                         ("recurrentgemma-2b", "hybrid")):
        model = build_model(get_smoke_config(arch))
        with pytest.raises(KVQuantUnsupported) as ei:
            model.init_cache(2, 16, kv_quant=True)
        assert ei.value.reason == f"kv_quant_unsupported:{family}"
        assert isinstance(ei.value, ValueError)
        # kv_quant=False still works: the unified signature is accepted
        model.init_cache(2, 16, kv_quant=False)


def test_engine_capability_reasons():
    """The engine and the plain serve smoke degrade through stable
    ``key:detail`` reasons shared with benchmarks and launch."""
    ssm = build_model(get_smoke_config("mamba2-130m"))
    assert serve_capability(ssm, engine=True) == (False,
                                                  "unsupported_family:ssm")
    mla = build_model(get_smoke_config("deepseek-v3-671b"))
    assert serve_capability(mla, engine=True) == (False,
                                                  "unsupported_layout:mla")
    assert serve_capability(mla, kv_quant=True) == (
        False, "kv_quant_unsupported:mla")
    ok, reason = serve_capability(mla)  # uniform fp serve smoke still fine
    assert ok and reason == "ok"
    with pytest.raises(KVQuantUnsupported) as ei:
        ServeEngine(ssm, None, None)
    assert ei.value.reason == "unsupported_family:ssm"


def test_admit_rejects_oversubscription(engine, deploy_lm):
    """More requests than free slots (or than the group size) is a host
    bug, reported eagerly instead of silently dropping a request."""
    cfg = deploy_lm[0]
    prompts = _prompts([4, 4, 4], cfg.vocab, seed=4)
    with pytest.raises(ValueError, match="free slots"):
        engine.admit([(300 + i, p, 2) for i, p in enumerate(prompts)])
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        engine.bucket_for(MAX_LEN + 1)


# ------------------------------------------------------------- observability
def test_detok_error_counted_and_reraised(engine, deploy_lm):
    """A raising detokenize callback must not kill the drain thread: the
    loop keeps consuming (so ``queue.join()`` never hangs), the error is
    counted on the scheduler's metrics, and the first exception is
    re-raised on the caller's thread — once; ``close()`` after the raise
    is clean."""
    cfg = deploy_lm[0]
    prompts = _prompts([4, 6], cfg.vocab, seed=5)
    poisoned = []

    def bad_detok(rid, tok):
        if rid == 400 and not poisoned:
            poisoned.append(tok)
            raise RuntimeError("tokenizer exploded")

    reqs = [Request(400 + i, p, max_new=3) for i, p in enumerate(prompts)]
    sched = Scheduler(engine, detokenize=bad_detok)
    try:
        with pytest.raises(RuntimeError, match="tokenizer exploded"):
            sched.run(reqs)
        sched.close()  # joins the drain thread; cleared error, no re-raise
        assert sched.metrics.detok_errors >= 1
        assert sched.outputs[400], "drain loop died at the poisoned token"
    finally:
        while engine.active:  # leave the shared engine idle for later tests
            engine.step()
        engine.drain_finished()


def test_prefill_latency_histogram_accumulates(engine, deploy_lm):
    """Per-bucket prefill latency is a histogram, not a last-write scalar:
    repeated admits into the same bucket all survive into the summary
    (count grows; p50/p95 exposed through ``engine.stats()``)."""
    cfg = deploy_lm[0]
    before = engine.metrics.prefill_hist(8).count
    for seed in (6, 7):
        toks = _prompts([4], cfg.vocab, seed=seed)[0]
        engine.admit([(500 + seed, toks, 2)])
        while engine.active:
            engine.step()
        engine.drain_finished()
    s = engine.stats()["prefill_us"][8]
    assert s["count"] == before + 2, "prefill histogram overwrote a sample"
    assert {"count", "mean", "p50", "p95", "max"} <= set(s)
    assert 0 < s["p50"] <= s["p95"] <= s["max"]
