"""Recorder for the reconstruction RNG-parity fixtures.

``recon_legacy_trajectories.npz`` pins the trajectories the *legacy
per-iteration Python loop* produced for a fixed set of recipes/blocks/keys.
It was recorded at commit 807104f (the last commit carrying the
``--legacy-loop`` escape hatch) by running this script; the legacy engine has
since been removed, so the fixture — not a live second engine — is the parity
oracle for the scan-fused engine (see tests/test_recon_engine.py).

Re-recording (only if the *intended* RNG stream or step math changes, which
is a breaking trajectory change that must be called out in the PR): run

    PYTHONPATH=src python tests/fixtures/record_fixtures.py [out.npz]

and commit the regenerated npz together with the engine change. Post-removal
re-records run the scan engine (the only one left): the new recording then
*becomes* the oracle for subsequent refactors.

``--only TAG`` (repeatable) re-records just the named entries and keeps
every other tag from the existing npz — so an intended trajectory change in
one path (e.g. the per-site minibatch keys of layer-wise recon) does not
silently refresh the oracles for untouched paths.

Recording history of intended trajectory changes since the legacy capture:
  - partitionable threefry (repro/__init__.py): sharding-invariant RNG is a
    hard requirement for data-parallel calibration (the legacy stream draws
    *different* QDrop masks when outputs are sharded), and it changes every
    random stream — all tags re-recorded.
  - layer-wise recon folds the site name into the minibatch key (sibling
    sites previously shared one gather schedule) — ``layerwise``
    re-recorded.
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, os.pardir, "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import QuantRecipe  # noqa: E402
from repro.core.context import QuantCtx  # noqa: E402
from repro.core.reconstruct import (BlockHandle, Site, quantize_blocks,  # noqa: E402
                                    reconstruct_block)

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "recon_legacy_trajectories.npz")

# The committed npz was recorded by the *legacy per-iteration loop* at
# commit 807104f (reconstruct_block(..., engine="legacy"), an argument that
# no longer exists). Re-records at head run the current scan engine.


def flatten_tree(prefix, tree):
    """Pytree -> {"prefix/<path>": np.ndarray} with deterministic path
    strings (DictKey -> key, SequenceKey -> [i]). Must stay in sync with the
    copy in tests/test_recon_engine.py."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        toks = []
        for p in path:
            if hasattr(p, "key"):
                toks.append(str(p.key))
            elif hasattr(p, "idx"):
                toks.append(f"[{p.idx}]")
            else:
                toks.append(str(p))
        out[prefix + "/" + "|".join(toks)] = np.asarray(leaf)
    return out


def make_block(key, name, d=24, h=40, token=None):
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (d, h), jnp.float32) * d**-0.5,
        "w2": jax.random.normal(k2, (h, d), jnp.float32) * h**-0.5,
    }

    def apply(p, x, ctx, _n=name):
        z = jax.nn.gelu(ctx.linear(f"{_n}.w1", x, p["w1"]))
        return ctx.linear(f"{_n}.w2", z, p["w2"]) + x

    sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
    return BlockHandle(name, params, apply, sites, apply_key=token)


def make_chain(n, token, d=24, h=40):
    keys = jax.random.split(jax.random.key(3), n)
    return [make_block(k, f"layers.{i}", d=d, h=h, token=token)
            for i, k in enumerate(keys)]


def record_single(store, tag, recipe, block_key, x_key, n, seed=3):
    block = make_block(jax.random.key(block_key), "layers.0")
    x = jax.random.normal(jax.random.key(x_key), (n, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    ws, as_, rep = reconstruct_block(block, recipe, x, y,
                                     jax.random.key(seed))
    store.update(flatten_tree(f"{tag}/wstates", ws))
    store.update(flatten_tree(f"{tag}/astates", as_))
    store[f"{tag}/err"] = np.asarray([rep.err_before, rep.err_after])
    store[f"{tag}/loss_curve"] = np.asarray(rep.loss_curve)
    store[f"{tag}/mse_curve"] = np.asarray(rep.mse_curve)


def record_block_w4a8_qdrop(store):
    # block mode, full path: LSQ co-training + QDrop RNG
    record_single(
        store, "block_w4a8_qdrop",
        QuantRecipe(method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
                    setting="qdrop", iters=50, lr=3e-3, batch_size=8),
        block_key=7, x_key=8, n=48)


def record_block_w4a8_qdrop_short(store):
    # short-horizon twin of block_w4a8_qdrop for the sharded parity tests:
    # over ~15 steps reduction-order drift cannot yet amplify through the
    # STE rounding boundaries, so the data-parallel run must match this
    # recording at the tight tolerance (see tests/test_sharded_recon.py)
    record_single(
        store, "block_w4a8_qdrop_short",
        QuantRecipe(method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
                    setting="qdrop", iters=15, lr=3e-3, batch_size=8),
        block_key=7, x_key=8, n=48)


def record_adaround_reg(store):
    # AdaRound annealed regularizer consuming the traced step index
    record_single(
        store, "adaround_reg",
        QuantRecipe(method="adaround", w_bits=4, w_symmetric=True, a_bits=None,
                    iters=40, lr=3e-3, batch_size=8),
        block_key=9, x_key=10, n=32)


def record_full_batch(store):
    # full-batch recon (bs == n skips the gather)
    record_single(
        store, "full_batch",
        QuantRecipe(method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
                    iters=30, lr=3e-3, batch_size=32),
        block_key=11, x_key=12, n=32)


def record_chain_mixed(store):
    # 3-block chain under mixed-precision rules
    recipe = QuantRecipe(
        method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
        setting="qdrop", iters=30, lr=3e-3, batch_size=8,
        rules=("layers.0.*:w_bits=8,lr=1e-3",
               "layers.2.w2:a_bits=none,method=adaround"))
    x = jax.random.normal(jax.random.key(1), (40, 24), jnp.float32)
    fin, ast, _ = quantize_blocks(make_chain(3, token=None), recipe, x,
                                  as_qtensor=False)
    store.update(flatten_tree("chain_mixed/finalized", fin))
    store.update(flatten_tree("chain_mixed/astates", ast))


def record_layerwise(store):
    # layer-wise (recon='layer') per-site sub-blocks
    recipe = QuantRecipe(method="flexround", w_bits=3, w_symmetric=True,
                         a_bits=None, recon="layer", iters=40, lr=3e-3,
                         batch_size=8)
    x = jax.random.normal(jax.random.key(2), (40, 24), jnp.float32)
    fin, _, reports = quantize_blocks(make_chain(2, token=None), recipe, x,
                                      as_qtensor=False)
    assert len(reports) == 4
    store.update(flatten_tree("layerwise/finalized", fin))


RECORDERS = {
    "block_w4a8_qdrop": record_block_w4a8_qdrop,
    "block_w4a8_qdrop_short": record_block_w4a8_qdrop_short,
    "adaround_reg": record_adaround_reg,
    "full_batch": record_full_batch,
    "chain_mixed": record_chain_mixed,
    "layerwise": record_layerwise,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("out", nargs="?", default=OUT)
    ap.add_argument("--only", action="append", default=None, metavar="TAG",
                    choices=sorted(RECORDERS),
                    help="re-record only these tags; every other tag is "
                         "carried over unchanged from the existing npz")
    args = ap.parse_args()

    tags = args.only or sorted(RECORDERS)
    store = {}
    if args.only and os.path.exists(args.out):
        keep = dict(np.load(args.out))
        store.update({k: v for k, v in keep.items()
                      if k.split("/", 1)[0] not in tags})
        print(f"merging: kept {len(store)} arrays from "
              f"{sorted({k.split('/', 1)[0] for k in store})}")

    for tag in tags:
        RECORDERS[tag](store)

    np.savez_compressed(args.out, **store)
    print(f"wrote {args.out}: {len(store)} arrays, "
          f"{os.path.getsize(args.out) / 1024:.1f} KiB")


if __name__ == "__main__":
    main()
