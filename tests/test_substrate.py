"""Substrate tests: optimizer, checkpointing (atomic/resume), data pipeline,
straggler policy, gradient compression, PTQ fault-tolerant restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.core import QuantRecipe
from repro.core.reconstruct import quantize_blocks
from repro.data import CalibrationSet, StragglerPolicy, SyntheticTokens, \
    assemble_global_batch
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.optim.compress import compressed_psum, compression_error

KEY = jax.random.key(0)


# ---------------------------------------------------------------- optimizer
def _quad_problem():
    target = jax.random.normal(KEY, (32, 16))
    params = {"w": jnp.zeros((32, 16))}
    def grad_fn(p):
        return {"w": p["w"] - target}
    return params, grad_fn, target


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_adam_converges(moment_dtype):
    cfg = AdamConfig(lr=0.1, moment_dtype=moment_dtype)
    params, grad_fn, target = _quad_problem()
    state = adam_init(params, cfg)
    for _ in range(200):
        params, state, _ = adam_update(grad_fn(params), state, params, cfg)
    err = float(jnp.linalg.norm(params["w"] - target) /
                jnp.linalg.norm(target))
    assert err < (0.05 if moment_dtype != "int8" else 0.15)


def test_adam_grad_clip():
    cfg = AdamConfig(lr=0.1, grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    state = adam_init(params, cfg)
    _, _, gnorm = adam_update({"w": jnp.full((4,), 100.0)}, state, params, cfg)
    assert float(gnorm) > 100.0  # reported norm is pre-clip


# -------------------------------------------------------------- checkpoints
def test_save_load_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": [jnp.float32(1.5), {"c": jnp.zeros((4,), jnp.int8)}]}
    p = str(tmp_path / "ck")
    save_pytree(p, tree, {"note": "x"})
    loaded, meta = load_pytree(p)
    assert meta["note"] == "x"
    np.testing.assert_array_equal(loaded["a"], np.arange(6).reshape(2, 3))
    assert loaded["b"][1]["c"].dtype == np.int8


def test_qtensor_checkpoint_roundtrip(tmp_path):
    from repro.core import rtn
    from repro.core.quant_config import QuantConfig
    from repro.core.qtensor import dequantize_qtensor
    qcfg = QuantConfig(bits=4, symmetric=False)
    w = jax.random.normal(KEY, (16, 8))
    qt = rtn.export(w, rtn.init(w, qcfg), qcfg, dtype=jnp.float32)
    p = str(tmp_path / "qt")
    save_pytree(p, {"w": qt})
    loaded, _ = load_pytree(p)
    np.testing.assert_allclose(np.asarray(dequantize_qtensor(qt)),
                               np.asarray(dequantize_qtensor(
                                   jax.tree.map(jnp.asarray, loaded["w"]))),
                               rtol=1e-6)


def test_checkpoint_manager_rolling(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.full((2,), float(s))})
    assert mgr.all_steps() == [2, 3]
    state, meta = mgr.restore()
    assert meta["step"] == 3 and float(state["x"][0]) == 3.0


def test_checkpoint_atomicity_never_corrupt(tmp_path):
    """A crash mid-save leaves the previous checkpoint intact (tmp+rename)."""
    p = str(tmp_path / "ck")
    save_pytree(p, {"v": jnp.float32(1.0)})
    # simulate a crashed writer: stale tmp dir lying around
    os.makedirs(p + ".tmp", exist_ok=True)
    with open(p + ".tmp/garbage", "w") as f:
        f.write("partial")
    loaded, _ = load_pytree(p)
    assert float(loaded["v"]) == 1.0
    save_pytree(p, {"v": jnp.float32(2.0)})  # recovers from stale tmp
    loaded, _ = load_pytree(p)
    assert float(loaded["v"]) == 2.0


def test_ptq_block_checkpoint_resume(tmp_path):
    """Kill the PTQ run after block 1 of 2; resume must equal a clean run."""
    from tests.test_reconstruct import make_mlp_block, _calib
    recipe = QuantRecipe(method="flexround", w_bits=8, iters=40,
                         batch_size=16, lr=2e-3, a_bits=None)
    b1 = make_mlp_block(jax.random.key(1), name="b1")
    b2 = make_mlp_block(jax.random.key(2), name="b2")
    x0 = _calib(jax.random.key(3))

    clean, _, _ = quantize_blocks([b1, b2], recipe, x0, as_qtensor=False)

    ckdir = str(tmp_path / "ptq")
    # run only block 1 then "crash" (simulated by a wrapper that raises)
    orig_apply = b2.apply

    def crashing_apply(p, x, ctx):
        if ctx.mode == "recon":
            raise RuntimeError("simulated node failure")
        return orig_apply(p, x, ctx)

    b2_crash = type(b2)(b2.name, b2.params, crashing_apply, b2.sites)
    with pytest.raises(RuntimeError):
        quantize_blocks([b1, b2_crash], recipe, x0, as_qtensor=False,
                        checkpoint_dir=ckdir)
    # restart with healthy block 2: resumes after block 1
    resumed, _, reports = quantize_blocks([b1, b2], recipe, x0,
                                          as_qtensor=False,
                                          checkpoint_dir=ckdir)
    for c, r in zip(jax.tree.leaves(clean[0]), jax.tree.leaves(resumed[0])):
        np.testing.assert_allclose(np.asarray(c), np.asarray(r), rtol=1e-6)


# --------------------------------------------------------------------- data
def test_synthetic_tokens_deterministic_and_sharded():
    src = SyntheticTokens(vocab=256, seq_len=16, seed=7)
    b1 = src.batch(step=3, batch_size=8)
    b2 = src.batch(step=3, batch_size=8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch(step=4, batch_size=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # per-host shards are disjoint draws and labels shift tokens by one
    h0 = src.batch(step=3, batch_size=8, host=0, n_hosts=2)
    h1 = src.batch(step=3, batch_size=8, host=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_calibration_set():
    src = SyntheticTokens(vocab=128, seq_len=8)
    cal = CalibrationSet.build(src, n_samples=10)
    assert cal.tokens.shape == (10, 8)


def test_straggler_assembly():
    src = SyntheticTokens(vocab=64, seq_len=4)
    shards = [jax.tree.map(np.asarray, src.batch(0, 4, host=h, n_hosts=4))
              for h in range(4)]
    shards[2] = None  # host 2 missed deadline
    batch, w = assemble_global_batch(shards, StragglerPolicy(min_fraction=0.5))
    assert batch["tokens"].shape == (4, 4)
    np.testing.assert_array_equal(np.asarray(w), [1, 1, 0, 1])
    with pytest.raises(TimeoutError):
        assemble_global_batch([shards[0], None, None, None],
                              StragglerPolicy(min_fraction=0.5))


# -------------------------------------------------------------- compression
def test_compression_error_small():
    g = jax.random.normal(KEY, (1000,))
    assert compression_error(g) < 0.02  # int8 block quant ~0.5% typical


def test_compressed_psum_shard_map():
    """Compressed all-reduce under shard_map == mean of shards (±int8 err)."""
    from jax.sharding import PartitionSpec as P

    from repro.optim.compress import shard_mapped_psum
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices")
    mesh = jax.make_mesh((jax.device_count(),), ("d",))
    g = jax.random.normal(KEY, (jax.device_count(), 64))

    def f(gs):
        red, _ = compressed_psum({"g": gs[0]}, "d")
        return red["g"][None]

    out = shard_mapped_psum(f, mesh, P("d", None), P("d", None))(g)
    want = jnp.mean(g, axis=0)
    for i in range(jax.device_count()):
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   rtol=0.05, atol=0.02)
