"""Data-parallel calibration: sharded reconstruction must be a pure
*placement* change — same RNG stream, same per-step math, same compile
counts as the single-device engine.

The debug-mesh (2x4) tests need 8 devices and are exercised by the
``recon-sharded-smoke`` CI job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; they skip elsewhere.
The single-device-mesh tests run everywhere (tier-1), pinning the sharded
code path itself — device_put placement, the stream/replicated sharding
constraints inside the scanned step, and the weighted objective — against
the recorded legacy trajectories.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import QuantRecipe
from repro.core import reconstruct as rec
from repro.core.context import QuantCtx
from repro.core.reconstruct import quantize_blocks, reconstruct_block
from repro.launch.mesh import dp_axes, make_debug_mesh
from repro.launch.sharding import stream_spec

from test_recon_engine import (FIXTURE, assert_matches_fixture, make_block,
                               make_chain)

RTOL = 2e-3


def _single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _debug_mesh_or_skip():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    return make_debug_mesh()


@pytest.fixture(scope="module")
def recorded():
    return dict(np.load(FIXTURE))


W4A8 = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True, a_bits=8,
                   setting="qdrop", iters=50, lr=3e-3, batch_size=8)
W4A8_SHORT = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, setting="qdrop", iters=15, lr=3e-3,
                         batch_size=8)
FULLBATCH = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                        a_bits=8, iters=30, lr=3e-3, batch_size=32)

# The data-parallel loss is a psum of per-shard partial sums; the resulting
# ~1e-7 reduction-order drift is amplified *chaotically* once trajectories
# cross STE rounding boundaries (deterministic per platform, but it forks
# the long-horizon path exactly like a jax-version bump does for the
# unsharded fixtures). Parity is therefore asserted in two regimes: exact
# (tight tolerance over a short horizon / the curve prefix, where drift
# cannot yet amplify) and quality (final recon error equivalent).
PREFIX = 12


def _run_single(recipe, block_key, x_key, n, *, mesh=None, sample_weight=None,
                seed=3):
    block = make_block(jax.random.key(block_key), "layers.0")
    x = jax.random.normal(jax.random.key(x_key), (n, 24), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    return reconstruct_block(block, recipe, x, y, jax.random.key(seed),
                             mesh=mesh, sample_weight=sample_weight)


def _check_against_fixture(recorded, tag, recipe, block_key, x_key, n, *,
                           mesh=None, sample_weight=None):
    ws, as_, rep = _run_single(recipe, block_key, x_key, n, mesh=mesh,
                               sample_weight=sample_weight)
    assert_matches_fixture(recorded, f"{tag}/wstates", ws, msg=f"{tag} mesh")
    assert_matches_fixture(recorded, f"{tag}/astates", as_, msg=f"{tag} mesh")
    np.testing.assert_allclose(np.asarray(rep.loss_curve),
                               recorded[f"{tag}/loss_curve"], rtol=RTOL,
                               atol=1e-5, err_msg=f"{tag} mesh: loss")
    np.testing.assert_allclose(np.asarray(rep.mse_curve),
                               recorded[f"{tag}/mse_curve"], rtol=RTOL,
                               atol=1e-5, err_msg=f"{tag} mesh: mse")


# ------------------------------------------------------- always-on coverage
def test_single_device_mesh_matches_recorded(recorded):
    """The sharded code path on a 1x1 mesh is the recorded trajectory."""
    _check_against_fixture(recorded, "block_w4a8_qdrop", W4A8,
                           block_key=7, x_key=8, n=48,
                           mesh=_single_device_mesh())


def test_all_ones_sample_weight_matches_unweighted(recorded):
    """weight=1 everywhere == the plain-mean objective (the straggler
    rescale B/weight.sum() degenerates to 1)."""
    _check_against_fixture(recorded, "block_w4a8_qdrop", W4A8,
                           block_key=7, x_key=8, n=48,
                           sample_weight=jnp.ones((48,), jnp.float32))


def test_zero_weight_samples_do_not_contribute():
    """Full-batch recon with garbage samples at weight 0 must land exactly
    where a run on the clean samples alone lands (bs==n on both sides, so
    the RNG streams coincide)."""
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=30, lr=3e-3, batch_size=64)
    block = make_block(jax.random.key(7), "layers.0")
    x_clean = jax.random.normal(jax.random.key(8), (16, 24), jnp.float32)
    y_clean = block.apply(block.params, x_clean, QuantCtx(mode="fp"))
    garbage = 100.0 * jax.random.normal(jax.random.key(9), (16, 24))
    x_all = jnp.concatenate([x_clean, garbage])
    y_all = jnp.concatenate([y_clean, jnp.zeros_like(y_clean)])
    w = jnp.concatenate([jnp.ones((16,)), jnp.zeros((16,))])

    ws_clean, _, _ = reconstruct_block(block, recipe, x_clean, y_clean,
                                       jax.random.key(3))
    ws_masked, _, rep = reconstruct_block(block, recipe, x_all, y_all,
                                          jax.random.key(3), sample_weight=w)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        ws_clean, ws_masked)
    assert np.isfinite(np.asarray(rep.loss_curve)).all()


def test_stream_spec_degrades_on_uneven_sample_counts():
    mesh = _single_device_mesh()
    assert stream_spec(5, mesh) == P(("data",))  # dp=1 divides everything
    if jax.device_count() >= 8:
        dmesh = make_debug_mesh()
        assert stream_spec(48, dmesh) == P(("data",))
        assert stream_spec(5, dmesh) == P()  # uneven -> replicated


# ------------------------------------------------------ debug-mesh (8 dev)
def test_debug_mesh_matches_recorded_block_exact(recorded):
    """Short horizon: the sharded run must reproduce the recorded states and
    full trajectories at the tight tolerance (same RNG, same schedule, same
    step math — sharding is purely a placement change here)."""
    _check_against_fixture(recorded, "block_w4a8_qdrop_short", W4A8_SHORT,
                           block_key=7, x_key=8, n=48,
                           mesh=_debug_mesh_or_skip())


def test_debug_mesh_long_run_quality_parity(recorded):
    """Full 50-step run: trajectory prefix exact, end state equivalent in
    quality (chaotic reduction-order amplification forks the late path; the
    recon error it lands on must not degrade)."""
    mesh = _debug_mesh_or_skip()
    _, _, rep = _run_single(W4A8, block_key=7, x_key=8, n=48, mesh=mesh)
    ref = recorded["block_w4a8_qdrop/loss_curve"]
    np.testing.assert_allclose(np.asarray(rep.loss_curve)[:PREFIX],
                               ref[:PREFIX], rtol=RTOL, atol=1e-5,
                               err_msg="sharded loss prefix")
    err_ref = recorded["block_w4a8_qdrop/err"][1]
    np.testing.assert_allclose(rep.err_after, err_ref, rtol=0.05,
                               err_msg="sharded err_after")
    assert np.isfinite(np.asarray(rep.loss_curve)).all()


def test_debug_mesh_matches_recorded_full_batch(recorded):
    """bs == n skips the gather: the full calibration tensors feed the step
    directly, so the whole objective reduces over the sharded axis. Prefix
    exact + quality at the end."""
    mesh = _debug_mesh_or_skip()
    _, _, rep = _run_single(FULLBATCH, block_key=11, x_key=12, n=32,
                            mesh=mesh)
    ref = recorded["full_batch/loss_curve"]
    np.testing.assert_allclose(np.asarray(rep.loss_curve)[:PREFIX],
                               ref[:PREFIX], rtol=RTOL, atol=1e-5,
                               err_msg="full-batch sharded loss prefix")
    np.testing.assert_allclose(rep.err_after, recorded["full_batch/err"][1],
                               rtol=0.05, err_msg="full-batch err_after")


def test_debug_mesh_streams_actually_sharded():
    """The point of the PR: calibration tensors must land distributed over
    the data axes, not replicated."""
    mesh = _debug_mesh_or_skip()
    from repro.launch.sharding import stream_sharding
    x = jax.device_put(jnp.zeros((48, 24)), stream_sharding(mesh, 48))
    assert not x.sharding.is_fully_replicated
    n_dp = np.prod([mesh.shape[a] for a in dp_axes(mesh)])
    assert x.addressable_shards[0].data.shape[0] == 48 // n_dp


def test_debug_mesh_compile_counts_flat_vs_unsharded(recorded):
    """Sharding must not break the compile-once cache: a 4-block chain under
    the debug mesh compiles exactly as many programs as unsharded, and the
    finalized params agree."""
    mesh = _debug_mesh_or_skip()
    # short horizon keeps the whole chain in the exact regime (finalize
    # turns any state drift into whole-grid-step code flips that the
    # advanced student stream then amplifies — see the PREFIX note); chunk <
    # iters still exercises multi-chunk carry donation
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=8, iters=6, lr=3e-3, batch_size=8)
    x = jax.random.normal(jax.random.key(4), (32, 24), jnp.float32)

    counts, outs = {}, {}
    for tag, m in (("unsharded", None), ("sharded", mesh)):
        rec.reset_engine_stats()
        rec.clear_engine_cache()
        fin, _, _ = quantize_blocks(make_chain(4, token=(object(),)), recipe,
                                    x, chunk=3, as_qtensor=False, mesh=m)
        counts[tag] = _compile_counts()
        outs[tag] = fin
    assert counts["sharded"] == counts["unsharded"], counts
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=RTOL, atol=1e-5),
        outs["unsharded"], outs["sharded"])


def _compile_counts():
    st = rec.engine_stats()
    return {"step": st.step_compiles, "teacher": st.teacher_compiles,
            "student": st.student_compiles,
            "recon_err": st.recon_error_compiles,
            "schedule": st.schedule_compiles, "total": st.compile_count}


def test_debug_mesh_probe_stays_compile_flat():
    """The allocator probe rides the same engine under a mesh: compiles
    O(distinct apply_keys x bits), identical to the unsharded pass."""
    mesh = _debug_mesh_or_skip()
    from repro.allocate import probe_blocks
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=1, batch_size=8)
    x = jax.random.normal(jax.random.key(5), (32, 24), jnp.float32)

    compiles, scores = {}, {}
    for tag, m in (("unsharded", None), ("sharded", mesh)):
        rec.reset_engine_stats()
        rec.clear_engine_cache()
        probe = probe_blocks(make_chain(3, token=(object(),)), recipe, x,
                             bits=(4, 8), mesh=m)
        compiles[tag] = probe.compile_count
        scores[tag] = probe
    assert compiles["sharded"] == compiles["unsharded"], compiles
    for site, per in scores["unsharded"].scores.items():
        for b, s in per.items():
            np.testing.assert_allclose(
                scores["sharded"].scores[site][b].mse, s.mse, rtol=RTOL,
                atol=1e-7, err_msg=f"{site}@{b}")
