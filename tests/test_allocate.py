"""Sensitivity-guided automatic mixed-precision allocator (repro.allocate).

Covers the subsystem's contracts:
  - probe scores behave (MSE falls with bits, cascade weights depth),
  - the probe pass compiles O(distinct apply_keys) steps, not O(sites),
  - greedy + exact-DP solvers satisfy the budget (DP no worse than greedy),
  - emitted rules resolve through QuantRecipe (including prefix-less sites),
  - auto allocation beats uniform W4 at avg_bits=4.5 on a block chain,
  - allocation round-trips through checkpoints: identical rules resume,
    mutated rules fail loudly with the allocation named.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.allocate import (AllocationReport, Budget, ProbeResult, SiteScore,
                            auto_allocate, probe_blocks, solve_allocation,
                            validate_budget)
from repro.core import QuantRecipe
from repro.core import reconstruct as rec
from repro.core.reconstruct import BlockHandle, Site, quantize_blocks


# ------------------------------------------------------------- test blocks
def make_chain(n, token, d=24, h=40, seed=3):
    blocks = []
    for i, key in enumerate(jax.random.split(jax.random.key(seed), n)):
        k1, k2 = jax.random.split(key)
        name = f"layers.{i}"
        params = {
            "w1": jax.random.normal(k1, (d, h), jnp.float32) * d**-0.5,
            "w2": jax.random.normal(k2, (h, d), jnp.float32) * h**-0.5,
        }

        def apply(p, x, ctx, _n=name):
            z = jax.nn.gelu(ctx.linear(f"{_n}.w1", x, p["w1"]))
            return ctx.linear(f"{_n}.w2", z, p["w2"]) + x

        sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
        blocks.append(BlockHandle(name, params, apply, sites,
                                  apply_key=token))
    return blocks


def make_prefixless_block(d=16):
    """A block whose sites have no 'layers.<i>.' prefix (embeddings/head)."""
    k1, k2 = jax.random.split(jax.random.key(0))
    params = {
        "embed": jax.random.normal(k1, (d, d), jnp.float32) * d**-0.5,
        "lm_head": jax.random.normal(k2, (d, d), jnp.float32) * d**-0.5,
    }

    def apply(p, x, ctx):
        h = ctx.linear("embed", x, p["embed"])
        return ctx.linear("lm_head", jax.nn.gelu(h), p["lm_head"])

    sites = {"embed": Site(("embed",)), "lm_head": Site(("lm_head",))}
    return BlockHandle("top", params, apply, sites)


RECIPE = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                     a_bits=None, iters=40, lr=3e-3, batch_size=8)
X = jax.random.normal(jax.random.key(1), (48, 24), jnp.float32)


# ------------------------------------------------------------------- probe
def test_probe_scores_monotone_and_cascade_weighted():
    blocks = make_chain(3, token=(object(),))
    probe = probe_blocks(blocks, RECIPE, X)
    assert probe.steps == 3 * 2 * 4  # blocks x sites x candidate bits
    assert probe.steps_per_s > 0
    for site, per in probe.scores.items():
        assert set(per) == {2, 3, 4, 8}
        assert per[2].mse > per[4].mse > per[8].mse >= 0
        assert per[2].fisher > per[4].fisher > per[8].fisher >= 0
        assert per[4].numel == 960
        # <=4-bit levels nibble-pack: half the code bytes of the 8-bit level
        assert per[8].cost_bytes - per[4].cost_bytes == 960 // 2
    # cascade weight = blocks remaining: depth 0 scores weigh 3x depth 2
    assert probe.scores["layers.0.w1"][4].cascade == 3.0
    assert probe.scores["layers.2.w1"][4].cascade == 1.0


def test_probe_compiles_per_apply_key_not_per_site():
    """The acceptance contract: probe-step traces scale with distinct
    apply_keys x candidate bits, flat in depth/site count."""
    counts = {}
    for n in (2, 6):
        rec.reset_engine_stats()
        rec.clear_engine_cache()
        probe = probe_blocks(make_chain(n, token=(object(),)), RECIPE, X)
        st = rec.engine_stats()
        counts[n] = (st.probe_compiles, st.teacher_compiles)
        assert probe.compile_count == sum(counts[n])
        assert probe.steps == n * 2 * 4  # runs scale, traces don't
    assert counts[2] == counts[6] == (4, 1), counts


# ------------------------------------------------------------------ solver
def _mk_probe(site_levels):
    """site_levels: {site: {bits: (mse, cost_bytes, numel)}} -> ProbeResult."""
    scores = {}
    for site, per in site_levels.items():
        scores[site] = {
            b: SiteScore(site=site, bits=b, mse=mse, fisher=0.0,
                         cost_bytes=cb, numel=numel)
            for b, (mse, cb, numel) in per.items()}
    return ProbeResult(scores=scores, steps=1, seconds=1.0, compile_count=0)


def test_greedy_and_dp_satisfy_budget_dp_no_worse():
    # crafted so plain greedy is suboptimal: B's cheap upgrade blocks A's
    # big one; the exact DP must find the better pairing
    probe = _mk_probe({
        "a": {2: (10.0, 2, 100), 8: (1.0, 6, 100)},
        "b": {2: (6.0, 2, 100), 4: (0.0, 4, 100)},
    })
    budget = Budget("weight_bytes", 8)
    got = {}
    for solver in ("greedy", "dp"):
        alloc = solve_allocation(probe, budget, objective="mse",
                                 solver=solver)
        assert alloc.cost <= alloc.capacity
        got[solver] = alloc
    assert got["dp"].predicted_score <= got["greedy"].predicted_score
    assert got["dp"].bits == {"a": 8, "b": 2}
    auto = solve_allocation(probe, budget, objective="mse", solver="auto")
    assert auto.solver == "dp"  # tiny grid: exact DP selected automatically
    assert auto.predicted_score == got["dp"].predicted_score


def test_avg_bits_budget_caps_weighted_average():
    probe = _mk_probe({
        s: {b: (float(2 ** -b) * (10 if s == "hot" else 1),
                50 * b, 100)
            for b in (2, 4, 8)}
        for s in ("hot", "cold1", "cold2", "cold3")})
    alloc = solve_allocation(probe, Budget("avg_bits", 4.5), objective="mse")
    assert sum(100 * b for b in alloc.bits.values()) <= 4.5 * 400
    assert alloc.avg_bits <= 4.5
    assert alloc.bits["hot"] == 8  # the sensitive site gets the headroom


def test_infeasible_budget_raises():
    probe = _mk_probe({"a": {4: (1.0, 100, 100), 8: (0.0, 200, 100)}})
    with pytest.raises(ValueError, match="infeasible"):
        solve_allocation(probe, Budget("weight_bytes", 50))
    with pytest.raises(ValueError, match="infeasible"):
        solve_allocation(probe, Budget("avg_bits", 1.0))


def test_budget_validation_rejects_bad_kind():
    with pytest.raises(ValueError, match="budget kind"):
        Budget("bits_per_layer", 4)
    with pytest.raises(ValueError, match="must be > 0"):
        Budget("avg_bits", 0)


# ------------------------------------------------------- rules + round trip
def test_emitted_rules_resolve_to_chosen_bits():
    blocks = make_chain(3, token=(object(),))
    report = auto_allocate(blocks, RECIPE, X, Budget("avg_bits", 4.5))
    assert validate_budget(report)
    recipe = RECIPE.with_rules(*report.rules())
    for site, bits in report.bits().items():
        assert recipe.resolve(site).weight.bits == bits
    # later rules win: the allocation overrides a pre-existing user rule
    user = RECIPE.with_rules("layers.0.*:w_bits=2")
    recipe2 = user.with_rules(*report.rules())
    assert recipe2.resolve("layers.0.w1").weight.bits == \
        report.bits()["layers.0.w1"]


def test_allocator_covers_prefixless_sites():
    """Satellite contract: allocator-emitted rules must cover embeddings/
    head-style sites that carry no 'layers.<i>.' prefix."""
    block = make_prefixless_block()
    x = jax.random.normal(jax.random.key(2), (32, 16), jnp.float32)
    report = auto_allocate([block], RECIPE, x, Budget("avg_bits", 6.0))
    assert set(report.bits()) == {"embed", "lm_head"}
    recipe = RECIPE.with_rules(*report.rules())
    for site, bits in report.bits().items():
        assert recipe.resolve(site).weight.bits == bits
    # and the emitted recipe actually reconstructs + exports those sites
    fin, _, _ = quantize_blocks([block], dataclasses.replace(
        recipe, iters=2), x)
    from repro.core.qtensor import QTensor
    leaves = [l for l in jax.tree.leaves(
        fin[0], is_leaf=lambda l: isinstance(l, QTensor))
        if isinstance(l, QTensor)]
    assert sorted(q.bits for q in leaves) == sorted(report.bits().values())


def test_report_json_round_trip_and_digest():
    blocks = make_chain(2, token=(object(),))
    report = auto_allocate(blocks, RECIPE, X, Budget("avg_bits", 4.5))
    clone = AllocationReport.from_dict(report.to_dict())
    assert clone.digest() == report.digest()
    assert clone.bits() == report.bits()
    assert [r.pattern for r in clone.rules()] == \
        [r.pattern for r in report.rules()]
    # digest tracks the decision, not probe timings
    moved = AllocationReport.from_dict(
        {**report.to_dict(), "probe": {"steps": 0, "seconds": 9.9,
                                       "steps_per_s": 0,
                                       "compile_count": 0}})
    assert moved.digest() == report.digest()
    other = auto_allocate(blocks, RECIPE, X, Budget("avg_bits", 5.0))
    assert other.digest() != report.digest()


# ---------------------------------------------------------- quality gate
def test_auto_beats_uniform_w4_at_matched_budget_slack():
    """avg_bits=4.5 must strictly beat uniform W4 in aggregate recon MSE:
    the extra half bit lands at the sites the probe rates most sensitive."""
    token = (object(),)
    blocks = make_chain(4, token=token)
    uniform = RECIPE
    report = auto_allocate(blocks, uniform, X, Budget("avg_bits", 4.5))
    assert validate_budget(report)
    auto = uniform.with_rules(*report.rules())

    _, _, rep_u = quantize_blocks(blocks, uniform, X)
    _, _, rep_a = quantize_blocks(blocks, auto, X)
    err_u = sum(r.err_after for r in rep_u)
    err_a = sum(r.err_after for r in rep_a)
    assert err_a < err_u, (err_a, err_u)


# ------------------------------------------------------------- checkpoints
def _alloc_setup(tmp_path):
    blocks = make_chain(2, token=(object(),))
    base = dataclasses.replace(RECIPE, method="rtn", iters=1)
    report = auto_allocate(blocks, base, X, Budget("avg_bits", 4.5))
    recipe = base.with_rules(*report.rules())
    quantize_blocks(blocks, recipe, X, checkpoint_dir=str(tmp_path),
                    allocation=report.meta())
    report.save(str(tmp_path))
    return blocks, base, recipe, report


def test_checkpoint_resume_same_allocation_succeeds(tmp_path):
    blocks, base, recipe, report = _alloc_setup(tmp_path)
    from repro.checkpoint.checkpoint import PTQCheckpointer
    resumed = PTQCheckpointer(str(tmp_path)).load(
        blocks, recipe, allocation=report.meta())
    assert resumed is not None and resumed[0] == len(blocks)
    # the persisted AllocationReport round-trips with the same identity
    loaded = AllocationReport.load(str(tmp_path))
    assert loaded is not None and loaded.digest() == report.digest()
    # a full quantize_blocks resume replays cleanly under identical rules
    fin, _, _ = quantize_blocks(blocks, recipe, X,
                                checkpoint_dir=str(tmp_path),
                                allocation=report.meta())
    assert len(fin) == len(blocks)


def test_checkpoint_mutated_rules_fail_naming_allocation(tmp_path):
    blocks, base, recipe, report = _alloc_setup(tmp_path)
    from repro.checkpoint.checkpoint import PTQCheckpointer
    flipped = {s: (8 if b != 8 else 4) for s, b in report.bits().items()}
    mutated = base.with_rules(*recipe.rules,
                              *(f"{s}:w_bits={b}"
                                for s, b in flipped.items()))
    with pytest.raises(ValueError, match="emitted by allocation"):
        PTQCheckpointer(str(tmp_path)).load(blocks, mutated,
                                            allocation=report.meta())
    # the error names the allocation that produced the checkpoint
    with pytest.raises(ValueError, match=report.name):
        quantize_blocks(blocks, mutated, X, checkpoint_dir=str(tmp_path),
                        allocation=report.meta())


def test_checkpoint_different_allocation_digest_fails(tmp_path):
    blocks, base, recipe, report = _alloc_setup(tmp_path)
    other = auto_allocate(blocks, base, X, Budget("avg_bits", 5.5))
    from repro.checkpoint.checkpoint import PTQCheckpointer
    with pytest.raises(ValueError, match="resume mismatch.*allocation"):
        PTQCheckpointer(str(tmp_path)).load(blocks, recipe,
                                            allocation=other.meta())
    # dropping the allocation entirely must also fail loudly
    with pytest.raises(ValueError, match="no allocation"):
        PTQCheckpointer(str(tmp_path)).load(blocks, recipe, allocation=None)
