"""int8 KV-cache decode path: consistency vs bf16 cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.context import QuantCtx
from repro.models import build_model

B, S = 2, 32


def test_int8_kv_cache_decode_close_to_bf16():
    cfg = get_smoke_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ctx = QuantCtx(mode="fp")

    outs = {}
    for quant in (False, True):
        cache = model.init_cache(B, S + 4, kv_quant=quant)
        _, cache = model.prefill(params, tokens[:, :-1], cache, ctx)
        logits, _ = model.decode_step(params, tokens[:, -1:], cache,
                                      jnp.int32(S - 1), ctx)
        outs[quant] = np.asarray(logits, np.float32)

    # int8 cache must match bf16 cache decode closely (per-token scales)
    denom = np.abs(outs[False]).max()
    rel = np.abs(outs[True] - outs[False]).max() / denom
    assert rel < 0.05, f"int8 KV divergence {rel:.3f}"
    # and greedy tokens should agree
    np.testing.assert_array_equal(outs[True].argmax(-1),
                                  outs[False].argmax(-1))


def test_int8_kv_greedy_horizon_64_steps():
    """Long-horizon serving contract: 64 autoregressive greedy steps on
    the int8 cache emit exactly the fp-cache token stream — quantization
    error from quantize-on-append must not compound into a divergent
    trajectory (each step re-reads every cached position)."""
    H, P = 64, 8
    cfg = get_smoke_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab)
    ctx = QuantCtx(mode="fp")

    trajs = {}
    for quant in (False, True):
        cache = model.init_cache(B, P + H, kv_quant=quant)
        _, cache = model.prefill(params, prompt[:, :-1], cache, ctx)
        step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, ctx))
        tok, toks = prompt[:, -1:], []
        for i in range(H):
            logits, cache = step(params, tok, cache, jnp.int32(P - 1 + i))
            tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
            toks.append(np.asarray(tok[:, 0]))
        trajs[quant] = np.stack(toks)
    np.testing.assert_array_equal(trajs[True], trajs[False])
