"""int8 KV-cache decode path: consistency vs bf16 cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.context import QuantCtx
from repro.models import build_model

B, S = 2, 32


def test_int8_kv_cache_decode_close_to_bf16():
    cfg = get_smoke_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ctx = QuantCtx(mode="fp")

    outs = {}
    for quant in (False, True):
        cache = model.init_cache(B, S + 4, kv_quant=quant)
        _, cache = model.prefill(params, tokens[:, :-1], cache, ctx)
        logits, _ = model.decode_step(params, tokens[:, -1:], cache,
                                      jnp.int32(S - 1), ctx)
        outs[quant] = np.asarray(logits, np.float32)

    # int8 cache must match bf16 cache decode closely (per-token scales)
    denom = np.abs(outs[False]).max()
    rel = np.abs(outs[True] - outs[False]).max() / denom
    assert rel < 0.05, f"int8 KV divergence {rel:.3f}"
    # and greedy tokens should agree
    np.testing.assert_array_equal(outs[True].argmax(-1),
                                  outs[False].argmax(-1))
