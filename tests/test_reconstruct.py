"""Reconstruction-engine tests: paper quality ordering on a toy problem.

These are the executable versions of the paper's core claims:
  - FlexRound recon error < RTN (strictly, it learns)
  - FlexRound <= AdaRound at the same budget (Table 2 ordering, toy proxy)
  - learnable s1 (ablation 1) and s3 (ablation 2) help
  - block-wise recon <= layer-wise recon error on the block output (Table 7)
"""
import jax
import jax.numpy as jnp

from repro.core import QuantRecipe
from repro.core.context import QuantCtx
from repro.core.reconstruct import (BlockHandle, Site, quantize_blocks,
                                    reconstruct_block, recon_error,
                                    init_wstates, init_astates, finalize_block)

KEY = jax.random.key(42)


def make_mlp_block(key, d_in=32, d_hidden=64, d_out=32, name="blk"):
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (d_in, d_hidden), jnp.float32) * (d_in**-0.5),
        "w2": jax.random.normal(k2, (d_hidden, d_out), jnp.float32) * (d_hidden**-0.5),
        "b1": jnp.zeros((d_hidden,), jnp.float32),
    }

    def apply(p, x, ctx):
        h = jax.nn.gelu(ctx.linear(f"{name}.w1", x, p["w1"], p["b1"]))
        return ctx.linear(f"{name}.w2", h, p["w2"]) + x  # residual

    sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
    return BlockHandle(name, params, apply, sites)


def _calib(key, n=64, d=32):
    return jax.random.normal(key, (n, d), jnp.float32)


def _run(method, key=KEY, iters=150, w_bits=4, a_bits=None, **kw):
    recipe = QuantRecipe(method=method, w_bits=w_bits, w_symmetric=True,
                         a_bits=a_bits, iters=iters, lr=3e-3, batch_size=16,
                         setting=kw.pop("setting", "qdrop"), **kw)
    block = make_mlp_block(jax.random.key(7))
    x = _calib(jax.random.key(8))
    y_fp = block.apply(block.params, x, QuantCtx(mode="fp"))
    ws, as_, rep = reconstruct_block(block, recipe, x, y_fp, key)
    # deployed (hard-export) error — what the paper's tables measure
    deployed = finalize_block(block, recipe, ws, as_qtensor=False)
    y_q = block.apply(deployed, x, QuantCtx(mode="deploy", recipe=recipe,
                                            astates=as_))
    rep.err_deploy = float(jnp.mean((y_q - y_fp) ** 2))
    return rep


def test_flexround_beats_rtn():
    rep = _run("flexround")
    assert rep.err_after < rep.err_before * 0.9  # learning strictly helps


def test_paper_method_ordering_toy():
    """FlexRound <= AdaRound on deployed weights at same budget (Table 2)."""
    fr = _run("flexround")
    ar = _run("adaround")
    rt = _run("rtn")
    assert fr.err_deploy <= ar.err_deploy * 1.25  # allow noise; usually smaller
    assert fr.err_deploy < rt.err_deploy
    assert ar.err_deploy < rt.err_deploy


def test_adaquant_learns_too():
    aq = _run("adaquant")
    assert aq.err_after < aq.err_before


def test_ablation1_learnable_s1_helps():
    """Fixed s1 (AdaRound-style constraint) vs learnable s1 (FlexRound)."""
    import repro.core.flexround as frm
    orig = frm.trainable
    try:
        frm.trainable = lambda st: {k: (k not in ("zero", "s1")) for k in st}
        fixed = _run("flexround", w_bits=3)
    finally:
        frm.trainable = orig
    learn = _run("flexround", w_bits=3)
    assert learn.err_after <= fixed.err_after * 1.10


def test_ablation2_s3_helps():
    import repro.core.flexround as frm
    orig = frm.trainable
    try:  # freeze s3 => pure s2 variant (Ablation Study 2)
        frm.trainable = lambda st: {k: (k not in ("zero", "s3", "s4")) for k in st}
        no_s3 = _run("flexround", w_bits=3)
    finally:
        frm.trainable = orig
    with_s3 = _run("flexround", w_bits=3)
    assert with_s3.err_after <= no_s3.err_after * 1.15


def test_wa_quant_with_lsq_and_qdrop():
    rep = _run("flexround", a_bits=8, setting="qdrop")
    assert rep.err_after < rep.err_before


def test_quantize_blocks_chain_and_deploy():
    """Two-block chain: quantize sequentially, check deploy consistency."""
    recipe = QuantRecipe(method="flexround", w_bits=8, a_bits=8, iters=60,
                         batch_size=16, lr=2e-3)
    b1 = make_mlp_block(jax.random.key(1), name="b1")
    b2 = make_mlp_block(jax.random.key(2), name="b2")
    x0 = _calib(jax.random.key(3))
    finalized, astates, reports = quantize_blocks([b1, b2], recipe, x0)
    assert len(finalized) == 2 and len(reports) == 2

    # deploy-mode end-to-end error should be small at 8-bit
    y_fp = x0
    for b in (b1, b2):
        y_fp = b.apply(b.params, y_fp, QuantCtx(mode="fp"))
    y_q = x0
    for b, p in zip((b1, b2), finalized):
        y_q = b.apply(p, y_q, QuantCtx(mode="deploy", recipe=recipe,
                                       astates=astates))
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05

    # QTensor leaves really are integer-coded
    from repro.core.qtensor import QTensor
    leaves = jax.tree.leaves(finalized[0],
                             is_leaf=lambda l: isinstance(l, QTensor))
    assert any(isinstance(l, QTensor) for l in leaves)


def test_block_recon_beats_layer_recon_on_block_output():
    """Table 7 rationale: block-wise objective gives lower block-output error."""
    b = make_mlp_block(jax.random.key(5))
    x = _calib(jax.random.key(6))
    y_fp = b.apply(b.params, x, QuantCtx(mode="fp"))
    errs = {}
    for unit in ("block", "layer"):
        recipe = QuantRecipe(method="flexround", w_bits=3, w_symmetric=True,
                             iters=150, batch_size=16, recon=unit, lr=3e-3)
        finalized, astates, _ = quantize_blocks([b], recipe, x,
                                                as_qtensor=False)
        y = b.apply(finalized[0], x, QuantCtx(mode="deploy", recipe=recipe,
                                              astates=astates))
        errs[unit] = float(jnp.mean((y - y_fp) ** 2))
    assert errs["block"] <= errs["layer"] * 1.05


def test_recon_respects_seed_determinism():
    r1 = _run("flexround", key=jax.random.key(9), iters=40)
    r2 = _run("flexround", key=jax.random.key(9), iters=40)
    assert r1.err_after == r2.err_after
