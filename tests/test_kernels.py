"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flexround, rtn
from repro.core.quant_config import QuantConfig
from repro.kernels import ref
from repro.kernels.dequant_matmul_w4 import dequant_matmul_w4
from repro.kernels.flexround_quant import flexround_quant
from repro.kernels.qmatmul_int8 import qmatmul_int8

KEY = jax.random.key(0)

SHAPES_MN = [(8, 128), (64, 256), (100, 384), (256, 512)]
SHAPES_MKN = [(8, 128, 128), (32, 256, 128), (64, 512, 384), (16, 130, 256)]


@pytest.mark.parametrize("shape", SHAPES_MN)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("per_channel", [False, True])
def test_flexround_quant_kernel(shape, dtype, per_channel):
    M, N = shape
    k1, k2, k3 = jax.random.split(KEY, 3)
    w = (jax.random.normal(k1, (M, N), jnp.float32) * 0.1).astype(dtype)
    s2 = jnp.exp(0.05 * jax.random.normal(k2, (M, N), jnp.float32))
    if per_channel:
        s1 = jnp.exp(jax.random.normal(k3, (1, N)) * 0.1) * 0.01
        zero = jnp.round(jax.random.uniform(k3, (1, N)) * 8)
    else:
        s1 = jnp.full((1, 1), 0.01, jnp.float32)
        zero = jnp.full((1, 1), 7.0, jnp.float32)
    s3 = jnp.exp(0.05 * jax.random.normal(k3, (1, N), jnp.float32))
    got = flexround_quant(w, s1, s2, s3, zero, qmin=0, qmax=15,
                          block_m=64, block_n=128, interpret=True)
    want = ref.flexround_quant_ref(w, s1, s2, s3, zero, 0, 15)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_flexround_kernel_matches_core_apply():
    """Kernel forward == core.flexround.apply forward (per-tensor symmetric)."""
    qcfg = QuantConfig(bits=4, symmetric=True, observer="minmax")
    w = jax.random.normal(KEY, (64, 128), jnp.float32) * 0.2
    st = flexround.init(w, qcfg)
    st = dict(st, s2=jnp.exp(0.03 * jax.random.normal(KEY, w.shape)))
    want = flexround.apply(w, st, qcfg)
    got = flexround_quant(
        w, jnp.broadcast_to(st["s1"], (1, 128)), st["s2"],
        jnp.broadcast_to(st["s3"], (1, 128)),
        jnp.broadcast_to(st["zero"], (1, 128)),
        qmin=qcfg.qmin, qmax=qcfg.qmax, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mkn", SHAPES_MKN)
@pytest.mark.parametrize("per_channel", [False, True])
def test_qmatmul_int8_kernel(mkn, per_channel):
    M, K, N = mkn
    k1, k2, k3 = jax.random.split(KEY, 3)
    a_q = jax.random.randint(k1, (M, K), -128, 128, jnp.int8)
    b_q = jax.random.randint(k2, (K, N), -128, 128, jnp.int8)
    a_scale, a_zero = jnp.float32(0.05), jnp.float32(3.0)
    b_scale = (jnp.exp(jax.random.normal(k3, (1, N)) * 0.2) * 0.01
               if per_channel else jnp.full((1, 1), 0.01))
    got = qmatmul_int8(a_q, b_q, a_scale, a_zero, b_scale,
                       block_m=32, block_n=128, block_k=64, interpret=True)
    want = ref.qmatmul_int8_ref(a_q, b_q, a_scale, a_zero, b_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mkn", [(8, 128, 128), (32, 256, 256),
                                 (64, 512, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_w4_kernel(mkn, dtype):
    M, K, N = mkn
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = (jax.random.normal(k1, (M, K), jnp.float32) * 0.5).astype(dtype)
    codes = jax.random.randint(k2, (K // 2, N), 0, 256).astype(jnp.uint8)
    scale = jnp.exp(jax.random.normal(k3, (1, N)) * 0.2) * 0.02
    zero = jnp.round(jax.random.uniform(k3, (1, N)) * 15)
    got = dequant_matmul_w4(x, codes, scale, zero, block_m=32, block_n=128,
                            block_k=128, interpret=True)
    want = ref.dequant_matmul_w4_ref(x, codes, scale, zero)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_qtensor_matmul_paths():
    """ops.qtensor_matmul agrees with dequant matmul for int8 and int4
    (kernel dispatch pinned to the Pallas path; the backend-policy and
    xla-path coverage lives in tests/test_deploy_parity.py)."""
    from repro.kernels import ops as kops
    for bits in (8, 4):
        qcfg = QuantConfig(bits=bits, symmetric=False, observer="minmax",
                           granularity="per_channel")
        w = jax.random.normal(KEY, (128, 64), jnp.float32) * 0.1
        st = rtn.init(w, qcfg)
        qt = rtn.export(w, st, qcfg, dtype=jnp.float32)
        x = jax.random.normal(jax.random.key(1), (4, 16, 128), jnp.float32)
        from repro.core.qtensor import dequantize_qtensor
        want = x @ dequantize_qtensor(qt)
        got = kops.qtensor_matmul(x, qt, backend="pallas", interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("packed", [True, False])
def test_dequant_matmul_batched_kernel(packed):
    """Grid-extended expert variant vs the per-expert jnp oracle."""
    from repro.kernels.dequant_matmul_w4 import dequant_matmul_batched
    E, M, K, N = 3, 16, 128, 256
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (E, M, K), jnp.float32) * 0.5
    kc = K // 2 if packed else K
    codes = jax.random.randint(k2, (E, kc, N), 0, 256).astype(jnp.uint8)
    scale = jnp.exp(jax.random.normal(k3, (E, 1, N)) * 0.2) * 0.02
    zero = jnp.round(jax.random.uniform(k3, (E, 1, N)) * 15)
    got = dequant_matmul_batched(x, codes, scale, zero, packed=packed,
                                 block_m=8, block_n=128, block_k=64,
                                 interpret=True)
    want = ref.dequant_matmul_batched_ref(x, codes, scale, zero, packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mkn", [(8, 128, 128), (16, 130, 256)])
def test_dequant_matmul_w8_kernel(mkn):
    from repro.kernels.dequant_matmul_w4 import dequant_matmul_w8
    M, K, N = mkn
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (M, K), jnp.float32) * 0.5
    codes = jax.random.randint(k2, (K, N), 0, 256).astype(jnp.uint8)
    scale = jnp.exp(jax.random.normal(k3, (1, N)) * 0.2) * 0.02
    zero = jnp.round(jax.random.uniform(k3, (1, N)) * 255)
    got = dequant_matmul_w8(x, codes, scale, zero, block_m=8, block_n=128,
                            block_k=64, interpret=True)
    want = ref.dequant_matmul_w8_ref(x, codes, scale, zero)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
