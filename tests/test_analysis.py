"""quantlint (repro.analysis): the analyzers must flag exactly the seeded
shipped regressions — the PR 5 ``a_state`` drop, a per-layer retrace, an
int16 matmul accumulator, subnormal FlexRound scale products, a lost
shard_map psum — and stay quiet on the current clean code.

The seeded bugs are real bugs this repo shipped (or nearly shipped) and
fixed: ``_matmul_2d`` silently dropping ``a_state`` off the int8 path
degrades serving to the un-snapped grid, per-layer retraces are what the
engine cache exists to prevent, and the QL3xx fixtures are the numerics
hazards quantcheck's abstract interpreter and shard checker exist to prove
absent.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import RetraceError, no_retrace
from repro.analysis import ast_rules, jaxpr_checks, trace
from repro.analysis.allowlist import default_allowlist
from repro.analysis.coverage import FALLBACK, kernel_coverage
from repro.analysis.intervals import check_intervals
from repro.analysis.report import AllowEntry, Finding, Report
from repro.analysis.shardcheck import check_shard_safety


# ------------------------------------------------------------- report layer
def test_report_allowlist_downgrades_with_reason():
    rep = Report()
    rep.add("QL201", "unused-input", "error", "jaxpr:e#x", "dead")
    rep.add("QL201", "unused-input", "error", "jaxpr:other#y", "dead")
    out = rep.apply_allowlist([AllowEntry("QL201", "jaxpr:e#*", "by design")])
    assert out.exit_code() == 1  # the unmatched finding still fails
    kept = {f.where: f for f in out}
    assert kept["jaxpr:e#x"].severity == "info"
    assert kept["jaxpr:e#x"].allowlisted == "by design"
    assert kept["jaxpr:other#y"].severity == "error"


def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError):
        Finding("QL999", "x", "fatal", "a:1", "m")


# ---------------------------------------------------------------- AST layer
BAD_SRC = '''
import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def step(x):
    t = time.time()
    r = np.random.rand()
    m = float(jnp.max(x))
    k = float(x.shape[0])
    return x * m + t + r

compiled = jax.jit(step)

def kern(x, interpret=True):
    return pl.pallas_call(lambda ref, o: None, out_shape=x)(x)
'''


def test_ast_rules_fire_on_seeded_source():
    rep = ast_rules.lint_source(BAD_SRC, "bad.py")
    rules = sorted({f.rule for f in rep})
    assert rules == ["QL101", "QL102", "QL103", "QL104", "QL105"]
    # the host-cast rule must not fire on float(<static shape int>)
    casts = [f for f in rep if f.rule == "QL102"]
    assert len(casts) == 1 and ":11" in casts[0].where


def test_ast_inline_suppression():
    src = ("import jax\n"
           "f = jax.jit(abs)  # quantlint: ignore[QL101]\n")
    assert len(ast_rules.lint_source(src, "s.py")) == 0
    src_other_rule = ("import jax\n"
                      "f = jax.jit(abs)  # quantlint: ignore[QL104]\n")
    assert len(ast_rules.lint_source(src_other_rule, "s.py")) == 1


def test_ast_clean_on_current_src():
    """Every QL1xx finding in src/ must be covered by the default allowlist
    (an intentional, documented violation) — new ones fail this test."""
    import os

    import repro
    pkg = os.path.dirname(os.path.abspath(repro.__file__))
    rep = ast_rules.lint_tree(os.path.dirname(pkg),
                              rel_to=os.path.dirname(os.path.dirname(pkg)))
    rep = rep.apply_allowlist(default_allowlist())
    assert rep.errors() == [], rep.pretty()


# -------------------------------------------------- QL201 unused input
def test_unused_input_flags_seeded_a_state_drop():
    entry = trace.qtensor_matmul_entry("w8a8", drop_a_state=True)
    rep = jaxpr_checks.check_unused_inputs(entry)
    wheres = sorted(f.where for f in rep.errors())
    assert len(wheres) == 2, rep.pretty(verbose=True)
    assert all("a_state" in w for w in wheres)


def test_unused_input_quiet_on_clean_matmul_layouts():
    for row in trace.MATMUL_LAYOUTS:
        entry = trace.qtensor_matmul_entry(row[0])
        rep = jaxpr_checks.check_entry(entry)
        assert rep.errors() == [], f"{row[0]}: {rep.pretty(verbose=True)}"


def test_recon_chunk_and_probe_clean():
    for entry in (trace.recon_chunk_entry(), trace.probe_entry()):
        rep = jaxpr_checks.check_entry(entry)
        assert rep.errors() == [], f"{entry.name}: {rep.pretty(verbose=True)}"
        # the one intentionally-dead leaf is allowlisted, visible as info
        infos = [f for f in rep if f.severity == "info"]
        if entry.name == "recon_chunk":
            assert any("steps" in f.where for f in infos)


def test_unused_input_respects_entry_allowlist():
    entry = trace.qtensor_matmul_entry("w8a8", drop_a_state=True)
    allowed = dataclasses.replace(entry, allow_unused=("a_state*",))
    rep = jaxpr_checks.check_unused_inputs(allowed)
    assert rep.errors() == []
    assert len([f for f in rep if f.severity == "info"]) == 2


# ------------------------------------------------------- QL203 donation
def test_donation_alias_detected():
    f = jax.jit(lambda a, b: (a + 1.0, b + 2.0), donate_argnums=(0, 1))
    x = jnp.ones((8,), jnp.float32)
    entry = trace.trace_jitted(f, (x, x), name="alias", argnames=("a", "b"),
                               donate_argnums=(0, 1))
    rep = jaxpr_checks.check_donation(entry)
    assert any("aliases the device buffer" in f.message
               for f in rep.errors()), rep.pretty(verbose=True)


def test_donation_clean_on_dealiased_chunk():
    entry = trace.recon_chunk_entry()
    assert jaxpr_checks.check_donation(entry).errors() == []


# ------------------------------------------------- QL204/QL206 negative
def test_f64_promotion_detected():
    with jax.experimental.enable_x64():
        f = jax.jit(lambda x: jnp.asarray(x, jnp.float64) * 2.0)
        entry = trace.trace_jitted(f, (jnp.ones((4,), jnp.float32),),
                                   name="f64", argnames=("x",))
        rep = jaxpr_checks.check_promotion(entry)
    assert any(f.rule == "QL204" for f in rep.errors())


def test_sharding_honesty_negative_control():
    """An unsharded jaxpr that *claims* a mesh must fail QL206."""
    if jax.device_count() < 8:
        pytest.skip("debug mesh needs 8 devices")
    from repro.launch.mesh import make_debug_mesh
    entry = trace.recon_chunk_entry()  # traced without a mesh
    fake = dataclasses.replace(entry, mesh=make_debug_mesh(), dp=("data",))
    assert jaxpr_checks.check_sharding(fake).exit_code() == 1


def test_sharded_chunk_constrains_dp_axes():
    if jax.device_count() < 8:
        pytest.skip("debug mesh needs 8 devices")
    from repro.launch.mesh import make_debug_mesh
    entry = trace.recon_chunk_entry(mesh=make_debug_mesh())
    rep = jaxpr_checks.check_entry(entry)
    assert rep.errors() == [], rep.pretty(verbose=True)


# -------------------------------------------------------- QL202 retrace
def test_retrace_flat_on_shared_token():
    rep = jaxpr_checks.check_retrace(per_layer=False)
    assert rep.exit_code() == 0, rep.pretty(verbose=True)


def test_retrace_flags_seeded_per_layer():
    rep = jaxpr_checks.check_retrace(per_layer=True)
    errs = rep.errors()
    assert len(errs) == 1 and errs[0].rule == "QL202", rep.pretty(True)
    assert "step +" in errs[0].message


def test_no_retrace_guard_raises(no_retrace):
    from repro.core import reconstruct as rec
    block = trace.toy_block(jax.random.key(41), "guard", token=None)
    recipe = trace.toy_recipe(iters=2, batch_size=2)
    x = jax.random.normal(jax.random.key(42), (2, 16))
    y = jax.random.normal(jax.random.key(43), (2, 16))
    with pytest.raises(RetraceError):
        with no_retrace(0):
            rec.reconstruct_block(block, recipe, x, y, jax.random.key(0))


# ----------------------------------------------------- QL207 coverage
def test_coverage_names_conv_fallback_sites():
    rep, rows = kernel_coverage()
    by_site = {r.site: r for r in rows}
    assert by_site["w8a8"].kernel == "qmatmul_int8_ref"
    assert by_site["w4_packed"].kernel == "dequant_matmul_w4_ref"
    assert by_site["experts_batched"].kernel == "dequant_matmul_batched_ref"
    conv_sites = [s for s in by_site if ".conv" in s or "patch_embed" in s]
    assert len(conv_sites) == 3
    assert all(by_site[s].kernel == FALLBACK for s in conv_sites)
    flagged = {f.where.split(":", 1)[1] for f in rep.warnings()}
    assert flagged == set(conv_sites)
    # only the conv frontends fall back — every matmul layout has a kernel
    assert all(not by_site[r[0]].fallback for r in trace.MATMUL_LAYOUTS)


# ---------------------------------------------- QL110 allowlist staleness
def test_stale_allowlist_entry_errors_on_full_run():
    rep = Report()
    rep.add("QL201", "unused-input", "error", "jaxpr:e#x", "dead")
    entries = [AllowEntry("QL201", "jaxpr:e#*", "by design"),
               AllowEntry("QL104", "src/gone.py*", "kernel long deleted")]
    # partial runs never audit staleness (false positives by construction)
    assert rep.apply_allowlist(entries).by_rule("QL110") == []
    audited = rep.apply_allowlist(entries, report_stale=True)
    stale = audited.by_rule("QL110")
    assert len(stale) == 1 and "QL104" in stale[0].where, audited.pretty(True)
    assert "kernel long deleted" in stale[0].message
    assert audited.exit_code() == 1


# ------------------------------------------------- QL102 taint regression
def test_ql102_quiet_on_concrete_jnp_values():
    """Host casts of values *not* data-dependent on a tracer argument are
    fine (they run at trace time on concrete arrays) — the old rule flagged
    any jnp-rooted expression."""
    src = ("import jax\n"
           "import jax.numpy as jnp\n"
           "def f(x):\n"
           "    eps = float(jnp.float32(1e-6))\n"
           "    lr = float(jnp.asarray([0.1]).max())\n"
           "    return x * eps * lr\n"
           "g = jax.jit(f)\n")
    assert ast_rules.lint_source(src, "s.py").by_rule("QL102") == []


def test_ql102_taint_flows_through_assignment():
    src = ("import jax\n"
           "import jax.numpy as jnp\n"
           "def f(x):\n"
           "    y = jnp.abs(x)\n"
           "    z = y.sum()\n"
           "    return int(z)\n"
           "g = jax.jit(f)\n")
    flagged = ast_rules.lint_source(src, "s.py").by_rule("QL102")
    assert len(flagged) == 1 and ":6" in flagged[0].where


# ------------------------------------- QL301/302/303 interval interpreter
def test_intervals_flags_seeded_int16_accumulator():
    rep = check_intervals(trace.int8_overflow_entry())
    errs = rep.errors()
    assert errs and all(f.rule == "QL301" for f in errs), rep.pretty(True)
    assert any("int16" in f.message for f in errs)


def test_intervals_proves_w8a8_accumulator_fits_envelope():
    rep = check_intervals(trace.qtensor_matmul_entry("w8a8"))
    assert rep.errors() == [], rep.pretty(True)
    proofs = [f for f in rep if f.rule == "QL301" and f.severity == "info"]
    assert proofs and "proven" in proofs[0].message, rep.pretty(True)


def test_intervals_flags_seeded_scale_underflow():
    rep = check_intervals(trace.flexround_apply_entry(underflow=True))
    errs = rep.errors()
    assert errs and all(f.rule == "QL303" for f in errs), rep.pretty(True)


def test_intervals_flags_provable_grid_saturation():
    f = jax.jit(lambda x: jnp.clip(jnp.round(x / 2.0), -7.0, 7.0))
    entry = trace.trace_jitted(f, (jnp.ones((8,), jnp.float32),),
                               name="sat", argnames=("x",),
                               ranges=(("x", 64.0, 256.0),))
    errs = check_intervals(entry).errors()
    assert errs and all(f.rule == "QL302" for f in errs), errs


def test_intervals_quiet_on_clean_entries():
    entries = (trace.flexround_apply_entry(), trace.recon_chunk_entry(),
               trace.probe_entry(), *trace.matmul_entries())
    for entry in entries:
        rep = check_intervals(entry)
        assert rep.errors() == [], f"{entry.name}: {rep.pretty(True)}"


# -------------------------------------------- QL305/306 shard safety
def test_shardcheck_flags_seeded_lost_psum():
    rep = check_shard_safety(trace.lost_psum_entry())
    errs = rep.errors()
    assert errs and all(f.rule == "QL305" for f in errs), rep.pretty(True)
    assert {f.name for f in errs} == {"collective-wrong-axis", "lost-psum"}


def test_shardcheck_quiet_on_sharded_recon():
    if jax.device_count() < 8:
        pytest.skip("debug mesh needs 8 devices")
    from repro.launch.mesh import make_debug_mesh
    entry = trace.recon_chunk_entry(mesh=make_debug_mesh())
    rep = check_shard_safety(entry)
    assert rep.errors() == [], rep.pretty(True)


# ------------------------------------------------- QL304 differential
def test_diffcheck_lattice_covers_edge_shapes():
    from repro.analysis.diffcheck import EXPECTED_KERNELS, shape_lattice
    for layout in EXPECTED_KERNELS:
        lat = shape_lattice(layout)
        assert len(lat) >= 20, (layout, len(lat))
        ks = {k for _, _, k, _ in lat}
        # grid-non-divisible K and (2-D layouts) multi-K-tile rows present
        assert any(k % 128 for k in ks), layout
        if layout != "experts_batched":
            assert any(k > 512 for k in ks), layout


def test_diffcheck_parity_cells_match_policy():
    from repro.analysis.diffcheck import EXPECTED_KERNELS, check_parity
    cells = [  # (layout, e, m, k, n, expected mode)
        ("w4_packed", 1, 5, 64, 24, "bit-exact"),      # single tile
        ("w8a8", 1, 5, 1024, 24, "bit-exact"),         # int32 path, 2 K tiles
        ("w8_weight_only", 1, 5, 1024, 24, "tolerance"),  # float, 2 K tiles
    ]
    for layout, e, m, k, n, mode in cells:
        row = check_parity(layout, e, m, k, n)
        assert row.ok and row.mode == mode, row
        assert (row.kernel_ref, row.kernel_pallas) == EXPECTED_KERNELS[layout]


# --------------------------------------------- seeded lint-run wiring
@pytest.mark.parametrize("bug,rule", [("int8_overflow", "QL301"),
                                      ("scale_underflow", "QL303"),
                                      ("lost_psum", "QL305")])
def test_seeded_quantcheck_runs_exit_nonzero(bug, rule):
    from repro.analysis import lint
    rep = lint.run_analysis(jaxpr_only=True, seed_bug=bug,
                            log=lambda *a, **k: None)
    assert rep.exit_code() == 1
    assert any(f.rule == rule for f in rep.errors()), rep.pretty(True)


# --------------------------------------------- QL4xx memcheck (liveness)
def test_memcheck_flags_seeded_dead_donation():
    from repro.analysis.memcheck import check_memory
    rep, rec = check_memory(trace.dead_donation_entry())
    errs = rep.errors()
    assert errs and all(f.rule == "QL402" for f in errs), rep.pretty(True)
    assert "no output shares its shape" in errs[0].message
    assert rec["donation_dead"] == 1 and rec["donation_matched"] == 0
    # QL203 must stay quiet on it: the donation is useless, not unsafe
    assert jaxpr_checks.check_donation(trace.dead_donation_entry()).errors() \
        == []


def test_memcheck_flags_donation_lifetime_overlap():
    """The second QL402 shape: a same-shape output exists but materializes
    while the donated buffer is still being read."""
    from repro.analysis.memcheck import check_memory

    def f(a):
        b = a * 2.0            # shape/dtype-matching candidate, defined early
        c = jnp.sum(a + b)     # ...but `a` is still read after b exists
        return b, c

    x = jnp.ones((16, 16), jnp.float32)
    entry = trace.trace_jitted(jax.jit(f, donate_argnums=(0,)), (x,),
                               name="overlap", argnames=("a",),
                               donate_argnums=(0,))
    errs = check_memory(entry)[0].errors()
    assert errs and all(f.rule == "QL402" for f in errs), errs
    assert "lifetimes overlap" in errs[0].message


def test_memcheck_flags_seeded_hbm_blowout():
    from repro.analysis.memcheck import check_memory
    rep, rec = check_memory(trace.hbm_blowout_entry())
    errs = rep.errors()
    assert errs and all(f.rule == "QL401" for f in errs), rep.pretty(True)
    # blows the budget both at the traced window and at the envelope
    assert len(errs) == 2
    assert rec["peak_trace_bytes"] > rec["budget_trace_bytes"]
    assert rec["peak_envelope_bytes"] > rec["budget_envelope_bytes"]


def test_memcheck_quiet_on_clean_entries():
    from repro.analysis.memcheck import check_memory
    entries = (trace.recon_chunk_entry(), trace.probe_entry(),
               trace.flexround_apply_entry(), *trace.matmul_entries())
    for entry in entries:
        rep, _ = check_memory(entry)
        assert rep.errors() == [], f"{entry.name}: {rep.pretty(True)}"


def test_memcheck_scan_carry_counted_once():
    """A donated-carry scan's memory is the carry once across the whole
    loop body — trip count must not multiply the peak."""
    from repro.analysis.memcheck import _walk_jaxpr

    def make(trips):
        def f(c):
            def body(carry, _):
                return carry * 0.5 + 1.0, None
            out, _ = jax.lax.scan(body, c, None, length=trips)
            return out
        x = jnp.ones((64, 64), jnp.float32)
        return trace.trace_jitted(jax.jit(f), (x,), name=f"scan{trips}",
                                  argnames=("c",))

    p2 = _walk_jaxpr(make(2).closed.jaxpr, 0).peak_at(0)
    p64 = _walk_jaxpr(make(64).closed.jaxpr, 0).peak_at(0)
    assert p2 == p64, (p2, p64)
    # sanity: the carry itself is in the peak
    assert p2 >= 64 * 64 * 4


def test_memcheck_static_kv_gap():
    """check_kv_static_gap proves int8-below-bf16 from per-token window
    bytes of the cache invars alone (and errors on the inverse)."""
    from repro.analysis.memcheck import check_kv_static_gap

    def mk(dtype, tag):
        cache = jnp.zeros((2, 24, 2, 16), dtype)
        p = jnp.ones((4,), jnp.float32)
        f = jax.jit(lambda p, c: c.astype(jnp.float32).sum() + p.sum())
        mem = trace.mem_contract((p, cache), max_len=24)
        return trace.trace_jitted(f, (p, cache),
                                  name=f"serve_decode[toy]{tag}",
                                  argnames=("params", "cache"), mem=mem)

    int8, bf16 = mk(jnp.int8, ""), mk(jnp.bfloat16, "[bf16-kv]")
    rep = check_kv_static_gap([int8, bf16])
    assert rep.errors() == [] and rep.by_rule("QL405"), rep.pretty(True)
    # inverse world: the "int8" cache grew past bf16 — must error
    fat = mk(jnp.float32, "")
    assert check_kv_static_gap([fat, bf16]).exit_code() == 1


@pytest.mark.parametrize("bug,rule", [("dead_donation", "QL402"),
                                      ("hbm_blowout", "QL401")])
def test_seeded_memcheck_runs_exit_nonzero(bug, rule):
    from repro.analysis import lint
    rep = lint.run_analysis(jaxpr_only=True, mem=True, seed_bug=bug,
                            log=lambda *a, **k: None)
    assert rep.exit_code() == 1
    assert any(f.rule == rule for f in rep.errors()), rep.pretty(True)


# ------------------------------------------ QL110 inline-ignore staleness
def test_stale_inline_ignore_errors_on_full_run():
    src = ("import jax\n"
           "x = 1  # quantlint: ignore[QL101]\n")
    # partial runs never audit staleness (mirrors the allowlist audit)
    assert ast_rules.lint_source(src, "s.py").by_rule("QL110") == []
    rep = ast_rules.lint_source(src, "s.py", report_stale_ignores=True)
    stale = rep.by_rule("QL110")
    assert len(stale) == 1 and ":2" in stale[0].where, rep.pretty(True)
    assert stale[0].name == "stale-inline-ignore"
    # a suppression that actually fired is not stale
    used = ("import jax\n"
            "f = jax.jit(abs)  # quantlint: ignore[QL101]\n")
    audited = ast_rules.lint_source(used, "s.py", report_stale_ignores=True)
    assert audited.by_rule("QL110") == [] and len(audited) == 0


def test_stale_ignore_scan_skips_docstrings():
    """Docstrings quoting the suppression syntax (this repo documents it in
    three places) are not suppressions — the scan is tokenizer-based."""
    src = ('"""Use `# quantlint: ignore[QL101]` to suppress."""\n'
           "x = 1\n")
    rep = ast_rules.lint_source(src, "s.py", report_stale_ignores=True)
    assert len(rep) == 0, rep.pretty(True)


# ------------------------------------------- roofline dtype accounting
def test_roofline_dtype_bytes_named_error_and_sub_byte():
    from repro.roofline.analysis import UnknownDtypeError, dtype_bytes
    assert dtype_bytes("s4") == 0.5
    assert dtype_bytes("u4") == 0.5
    assert dtype_bytes("int8") == 1    # numpy names map through NP_TO_HLO
    assert dtype_bytes("bf16") == 2
    with pytest.raises(UnknownDtypeError):
        dtype_bytes("float128")  # silent .get(dtype, 4) default is gone


def test_conv_fallback_warns_once_per_site():
    from repro.core import context as qctx
    qt = trace._export_qt((1, 3, 8, 16), 8)
    x = jax.random.normal(jax.random.key(44), (1, 2, 8, 8), jnp.float32)
    ctx = qctx.QuantCtx(mode="deploy", backend="xla")
    site = "test.analysis.conv_warn_once"
    qctx._CONV_FALLBACK_WARNED.discard(site)
    with warnings.catch_warnings(record=True) as w1:
        warnings.simplefilter("always")
        ctx.conv2d(site, x, qt)
    msgs = [str(w.message) for w in w1
            if issubclass(w.category, RuntimeWarning)]
    assert len(msgs) == 1
    assert "(1, 3, 8, 16)" in msgs[0] and "bytes" in msgs[0]
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        ctx.conv2d(site, x, qt)
    assert not [w for w in w2 if issubclass(w.category, RuntimeWarning)]
