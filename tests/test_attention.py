"""Attention primitive tests: chunked online-softmax (+causal q-chunking)
vs the dense oracle, GQA, local windows, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn

KEY = jax.random.key(0)


def _qkv(B=2, S=64, Hq=4, Hkv=2, D=16, Dv=None, key=KEY):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, Hkv, Dv or D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_full(chunk, causal):
    q, k, v = _qkv()
    got = attn.attention(q, k, v, causal=causal, chunk=chunk)
    want = attn.attention_full(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_causal_qchunk_path_triggered_and_exact():
    """S >> chunk triggers the q-chunked causal-skip path."""
    q, k, v = _qkv(S=128)
    got = attn.attention(q, k, v, causal=True, chunk=16)
    want = attn.attention_full(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_local_window():
    q, k, v = _qkv(S=64)
    got = attn.attention(q, k, v, causal=True, window=8, chunk=16)
    want = attn.attention_full(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_mla_style_asymmetric_v_dim():
    q, k, v = _qkv(D=24, Dv=16)
    got = attn.attention(q, k, v, causal=True, chunk=16)
    want = attn.attention_full(q, k, v, causal=True)
    assert got.shape[-1] == 16
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_kv_len_masking_matches_truncation():
    q, k, v = _qkv(S=64)
    q1 = q[:, :1]
    got = attn.attention(q1, k, v, causal=False, chunk=16,
                         kv_len=jnp.int32(40))
    want = attn.attention_full(q1, k[:, :40], v[:, :40], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_full_last_position():
    q, k, v = _qkv(S=32)
    out_full = attn.attention_full(q, k, v, causal=True)
    got = attn.decode_attention(q[:, -1:], k, v, jnp.int32(31))
    np.testing.assert_allclose(np.asarray(got), np.asarray(out_full[:, -1:]),
                               rtol=2e-5, atol=2e-5)


def test_uneven_kv_padding():
    q, k, v = _qkv(S=40)  # 40 % 16 != 0 -> internal padding
    got = attn.attention(q, k, v, causal=False, chunk=16)
    want = attn.attention_full(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
