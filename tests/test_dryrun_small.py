"""Sharding + dry-run machinery test at CI scale.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must precede jax import and must not leak into other tests), using
reduced configs on debug meshes (2,4) and (2,2,2): lower + compile every
family x step-kind, single- and multi-pod.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, sys
import jax
from repro.configs import get_smoke_config, get_shape
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import build_cell

archs = ["smollm-135m", "deepseek-v3-671b", "mamba2-130m",
         "recurrentgemma-2b", "whisper-medium", "phi-3-vision-4.2b"]
train = dataclasses.replace(get_shape("train_4k"), seq_len=64, global_batch=8)
dec = dataclasses.replace(get_shape("decode_32k"), seq_len=64, global_batch=8)
out = []
for mp in (False, True):
    mesh = make_debug_mesh(multi_pod=mp)
    for arch in archs:
        cfg = get_smoke_config(arch)
        for shp, w in ((train, "bf16"), (dec, "int8")):
            prog = build_cell(cfg, shp, mesh, weights=w)
            with mesh:
                c = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                            out_shardings=prog.out_shardings,
                            donate_argnums=prog.donate_argnums
                            ).lower(*prog.args).compile()
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):  # older jax: one dict per device
                ca = ca[0]
            out.append({"arch": arch, "kind": shp.kind, "mp": mp,
                        "flops": float(ca.get("flops", 0))})
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_machinery_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    rows = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(rows) == 24  # 6 archs x 2 kinds x 2 meshes
    assert all(r["flops"] > 0 for r in rows)
