"""Deploy-path parity suite: for every QTensor layout the serving path
supports (W4-packed, W8 weight-only, W8A8, batched expert weights), the
Pallas kernel (interpret mode), the pure-jnp ref oracle, and the plain
``dequantize_qtensor`` matmul must agree — and the ``backend="auto"`` policy
must resolve correctly off-TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flexround, lsq, rtn
from repro.core.context import QuantCtx
from repro.core.qtensor import QTensor, dequantize_qtensor
from repro.core.quant_config import QuantConfig, QuantRecipe
from repro.kernels import ops as kops
from repro.kernels import ref

KEY = jax.random.key(0)


def _export(shape, bits, granularity="per_channel", batch_dims=0):
    qcfg = QuantConfig(bits=bits, symmetric=False, observer="minmax",
                      granularity=granularity, batch_dims=batch_dims)
    w = jax.random.normal(KEY, shape, jnp.float32) * 0.1
    qt = rtn.export(w, rtn.init(w, qcfg), qcfg, dtype=jnp.float32)
    return qt


def _assert_parity(x, qt, want, **kw):
    """xla ref path and interpreted Pallas path both match ``want``."""
    for backend in ("xla", "pallas"):
        got = kops.qtensor_matmul(x, qt, backend=backend, interpret=True, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"backend={backend}")


@pytest.mark.parametrize("granularity", ["per_tensor", "per_channel"])
def test_w4_packed_parity(granularity):
    qt = _export((128, 64), 4, granularity)
    assert qt.packed and qt.pack_axis == 0
    x = jax.random.normal(jax.random.key(1), (3, 9, 128), jnp.float32)
    want = x @ dequantize_qtensor(qt)
    _assert_parity(x, qt, want)


@pytest.mark.parametrize("granularity", ["per_tensor", "per_channel"])
def test_w8_weight_only_parity(granularity):
    qt = _export((96, 48), 8, granularity)
    assert not qt.packed
    x = jax.random.normal(jax.random.key(2), (7, 96), jnp.float32)
    want = x @ dequantize_qtensor(qt)
    _assert_parity(x, qt, want)


def test_w4_unpacked_odd_dim_parity():
    """Odd d_in cannot nibble-pack; falls through to the W8-style kernel."""
    qt = _export((33, 48), 4)
    assert not qt.packed
    x = jax.random.normal(jax.random.key(3), (5, 33), jnp.float32)
    want = x @ dequantize_qtensor(qt)
    _assert_parity(x, qt, want)


def test_w8a8_parity():
    """Integer kernel == snapped-grid fake-quant matmul (exact) and ==
    LSQ fake-quant matmul (within one activation step)."""
    qt = _export((96, 48), 8)
    x = jax.random.normal(jax.random.key(4), (11, 96), jnp.float32)
    aq = QuantConfig(bits=8, symmetric=False, granularity="per_tensor",
                     observer="minmax")
    astate = lsq.init(jnp.asarray([float(x.min()), float(x.max())]), aq)
    a_scale, a_zero = lsq.deploy_astate(astate, aq)
    x_snap = a_scale * (jnp.clip(jnp.round(x / a_scale) + a_zero, 0, 255)
                        - a_zero)
    want = x_snap @ dequantize_qtensor(qt)
    _assert_parity(x, qt, want, a_state=(a_scale, a_zero))
    # the trained (fake-quant) forward differs only by the sub-step β snap
    x_lsq = lsq.apply(x, astate, aq)
    want_lsq = x_lsq @ dequantize_qtensor(qt)
    got = kops.qtensor_matmul(x, qt, a_state=(a_scale, a_zero), backend="xla")
    denom = float(jnp.linalg.norm(want_lsq)) + 1e-9
    assert float(jnp.linalg.norm(got - want_lsq)) / denom < 0.02


def test_w4a8_parity():
    """Regression: ``qtensor_matmul`` silently dropped ``a_state`` unless
    bits == 8, so direct kernel callers served W4A8 as W4A16 (the deploy
    ctx papered over it with the training-time ``lsq.apply`` grid instead
    of the snapped deploy grid). Packed-W4 matmul with a_state must equal
    the snapped-grid fake-quant matmul exactly, stay within one activation
    step of the recon-mode (LSQ fake-quant) numerics, and differ from the
    activation-fp result."""
    qt = _export((128, 64), 4)
    assert qt.packed and qt.pack_axis == 0
    x = jax.random.normal(jax.random.key(9), (11, 128), jnp.float32)
    aq = QuantConfig(bits=8, symmetric=False, granularity="per_tensor",
                     observer="minmax")
    astate = lsq.init(jnp.asarray([float(x.min()), float(x.max())]), aq)
    a_scale, a_zero = lsq.deploy_astate(astate, aq)
    x_snap = a_scale * (jnp.clip(jnp.round(x / a_scale) + a_zero, 0, 255)
                        - a_zero)
    want = x_snap @ dequantize_qtensor(qt)
    _assert_parity(x, qt, want, a_state=(a_scale, a_zero))
    # recon-mode numerics: LSQ fake-quant differs only by the sub-step β snap
    x_lsq = lsq.apply(x, astate, aq)
    want_recon = x_lsq @ dequantize_qtensor(qt)
    got = kops.qtensor_matmul(x, qt, a_state=(a_scale, a_zero), backend="xla")
    denom = float(jnp.linalg.norm(want_recon)) + 1e-9
    assert float(jnp.linalg.norm(got - want_recon)) / denom < 0.02
    # and the old dropped-a_state behavior (W4A16) is measurably different
    w4a16 = kops.qtensor_matmul(x, qt, backend="xla")
    assert float(jnp.linalg.norm(got - w4a16)) > 0


def test_w4a8_unpacked_odd_dim_parity():
    """Odd d_in (no nibble pack) with a_state: the weight-only kernel must
    see the same statically fake-quantized activations."""
    qt = _export((33, 48), 4)
    assert not qt.packed
    x = jax.random.normal(jax.random.key(10), (5, 33), jnp.float32)
    aq = QuantConfig(bits=8, symmetric=False, granularity="per_tensor",
                     observer="minmax")
    astate = lsq.init(jnp.asarray([float(x.min()), float(x.max())]), aq)
    a_scale, a_zero = lsq.deploy_astate(astate, aq)
    x_snap = a_scale * (jnp.clip(jnp.round(x / a_scale) + a_zero, 0, 255)
                        - a_zero)
    want = x_snap @ dequantize_qtensor(qt)
    _assert_parity(x, qt, want, a_state=(a_scale, a_zero))


def test_ctx_deploy_w4a8_routes_a_state_to_kernel():
    """Deploy-mode ctx must hand packed-W4 sites their static activation
    grid (the recipe says W4A8): output == kernel with a_state, != the
    weight-only (W4A16) result."""
    recipe = QuantRecipe(method="flexround", w_bits=4, a_bits=8)
    qt = _export((64, 32), 4)
    assert qt.packed
    x = jax.random.normal(jax.random.key(11), (6, 64), jnp.float32)
    aq = recipe.resolve("s").act
    astate = lsq.init(jnp.asarray([float(x.min()), float(x.max())]), aq)
    ctx = QuantCtx(mode="deploy", recipe=recipe, astates={"s": astate})
    got = ctx.linear("s", x, qt)
    want = kops.qtensor_matmul(x, qt,
                               a_state=lsq.deploy_astate(astate, aq))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    w4a16 = kops.qtensor_matmul(x, qt)
    assert float(jnp.linalg.norm(got - w4a16)) > 0


@pytest.mark.parametrize("bits", [4, 8])
def test_batched_expert_parity(bits):
    """batch_dims=1 stacked expert weights: per-expert kernel == per-expert
    dequant einsum. 4-bit packs along the contraction axis (pack_axis=1)."""
    qcfg = QuantConfig(bits=bits, symmetric=False, observer="minmax",
                       granularity="per_channel", batch_dims=1)
    w = jax.random.normal(KEY, (3, 128, 64), jnp.float32) * 0.1
    st = flexround.init(w, qcfg)
    qt = flexround.export(w, st, qcfg, dtype=jnp.float32)
    if bits == 4:
        assert qt.packed and qt.pack_axis == 1
        assert qt.codes.shape == (3, 64, 64)
    x = jax.random.normal(jax.random.key(5), (2, 3, 5, 128), jnp.float32)
    want = jnp.einsum("geni,eio->geno", x, dequantize_qtensor(qt))
    _assert_parity(x, qt, want)


def test_backend_auto_resolves_on_cpu():
    backend, interpret = kops.resolve_backend("auto")
    if jax.default_backend() == "tpu":
        assert backend == "pallas" and interpret is False
    else:
        # production serving off-TPU must not pay Pallas interpret overhead
        assert backend == "xla"
        assert kops.resolve_backend("pallas") == ("pallas", True)
    with pytest.raises(ValueError):
        kops.resolve_backend("cuda")


def test_ctx_linear_deploy_routes_through_kernels(monkeypatch):
    """Every deploy-mode QTensor matmul goes through kops.qtensor_matmul."""
    calls = []
    orig = kops.qtensor_matmul

    def spy(x, qt, **kw):
        calls.append(qt.shape)
        return orig(x, qt, **kw)

    monkeypatch.setattr(kops, "qtensor_matmul", spy)
    ctx = QuantCtx(mode="deploy")
    qt2 = _export((32, 16), 8)
    x2 = jax.random.normal(jax.random.key(6), (4, 32), jnp.float32)
    y2 = ctx.linear("site.a", x2, qt2)
    qcfg = QuantConfig(bits=4, symmetric=False, observer="minmax",
                       granularity="per_channel", batch_dims=1)
    w3 = jax.random.normal(KEY, (2, 32, 16), jnp.float32) * 0.1
    qt3 = rtn.export(w3, rtn.init(w3, qcfg), qcfg, dtype=jnp.float32)
    x3 = jax.random.normal(jax.random.key(7), (2, 3, 32), jnp.float32)
    y3 = ctx.linear("site.b", x3, qt3, batch_dims=1)
    assert calls == [(32, 16), (2, 32, 16)]
    np.testing.assert_allclose(np.asarray(y2),
                               np.asarray(x2 @ dequantize_qtensor(qt2)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y3),
        np.asarray(jnp.einsum("eni,eio->eno", x3, dequantize_qtensor(qt3))),
        rtol=1e-4, atol=1e-4)


def test_ctx_deploy_w8a8_uses_integer_path():
    """With static LSQ astates, deploy no longer fake-quantizes activations:
    output matches the integer kernel exactly."""
    recipe = QuantRecipe(method="flexround", w_bits=8, a_bits=8)
    qt = _export((64, 32), 8)
    x = jax.random.normal(jax.random.key(8), (6, 64), jnp.float32)
    aq = recipe.resolve("s").act
    astate = lsq.init(jnp.asarray([float(x.min()), float(x.max())]), aq)
    ctx = QuantCtx(mode="deploy", recipe=recipe, astates={"s": astate})
    got = ctx.linear("s", x, qt)
    want = kops.qtensor_matmul(x, qt,
                               a_state=lsq.deploy_astate(astate, aq))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_qtensor_pack_utilities():
    """pack()/unpack()/unpacked_codes() round-trip; repacking to a different
    axis preserves the dequantized tensor."""
    qt = _export((3, 16, 8), 4, batch_dims=1)
    assert qt.packed and qt.pack_axis == 1
    want = dequantize_qtensor(qt)
    unpacked = qt.unpack()
    assert not unpacked.packed and unpacked.codes.shape == (3, 16, 8)
    np.testing.assert_array_equal(np.asarray(dequantize_qtensor(unpacked)),
                                  np.asarray(want))
    repacked = unpacked.pack(axis=2)
    assert repacked.packed and repacked.pack_axis == 2
    assert repacked.codes.shape == (3, 16, 4)
    np.testing.assert_array_equal(np.asarray(dequantize_qtensor(repacked)),
                                  np.asarray(want))
    assert qt.pack() is qt  # no-op on same axis
    w8 = _export((16, 8), 8)
    assert w8.pack() is w8  # >4 bits never packs


def test_flexround_fake_quant_scalar_s1():
    """Regression: ops.flexround_fake_quant must honor scalar per-tensor
    s1/s3/zero (shape () or (1, 1)) exactly like per-channel rows."""
    qcfg = QuantConfig(bits=4, symmetric=True, observer="minmax")
    w = jax.random.normal(KEY, (16, 8), jnp.float32)
    s2 = jnp.exp(0.05 * jax.random.normal(jax.random.key(9), (16, 8)))
    for mk in (lambda v: jnp.float32(v),            # shape ()
               lambda v: jnp.full((1, 1), v)):      # shape (1, 1)
        st = {"s1": mk(0.01), "zero": mk(0.0), "s2": s2, "s3": mk(1.0)}
        want = ref.flexround_quant_ref(
            w, jnp.full((1, 8), 0.01), s2, jnp.ones((1, 8)),
            jnp.zeros((1, 8)), qcfg.qmin, qcfg.qmax)
        for backend in ("xla", "pallas"):
            got = kops.flexround_fake_quant(w, st, qcfg, backend=backend,
                                            interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-6)


def test_qmatmul_int8_asymmetric_weights():
    """b_zero correction: integer kernel == float dequant matmul for
    asymmetric weight grids (zero far from center)."""
    from repro.kernels.qmatmul_int8 import qmatmul_int8
    k1, k2 = jax.random.split(KEY)
    M, K, N = 16, 130, 48  # K not a block multiple: padding must stay exact
    a_q = jax.random.randint(k1, (M, K), -128, 128, jnp.int8)
    b_u = jax.random.randint(k2, (K, N), 0, 256).astype(jnp.uint8)
    b_scale = jnp.full((1, N), 0.02, jnp.float32)
    b_zero_u = jnp.round(jax.random.uniform(k2, (1, N)) * 255)
    a_scale, a_zero = jnp.float32(0.05), jnp.float32(-3.0)
    b_q = (b_u.astype(jnp.int32) - 128).astype(jnp.int8)
    b_zero = b_zero_u - 128.0
    want = ((a_scale * (a_q.astype(jnp.float32) - a_zero))
            @ (b_scale * (b_u.astype(jnp.float32) - b_zero_u)))
    got_ref = ref.qmatmul_int8_ref(a_q, b_q, a_scale, a_zero, b_scale,
                                   b_zero=b_zero)
    got_krn = qmatmul_int8(a_q, b_q, a_scale, a_zero, b_scale, b_zero,
                           block_m=8, block_n=16, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_krn), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_kernel_dispatch_compile_flat(no_retrace):
    """Once warmed, every kernel-table dispatch path reuses its compiled
    kernels: repeat calls with identical layouts trigger zero new XLA
    compilations (the tier-1 ``no_retrace`` fixture, counting backend
    compiles since the deploy path never touches the engine counters)."""
    cases = []
    for shape, bits, with_a in (((64, 32), 4, False), ((64, 32), 4, True),
                                ((48, 24), 8, True), ((48, 24), 8, False),
                                ((33, 24), 4, False)):
        qt = _export(shape, bits)
        x = jax.random.normal(jax.random.key(12), (5, shape[0]), jnp.float32)
        a_state = None
        if with_a:
            aq = QuantConfig(bits=8, symmetric=False,
                             granularity="per_tensor", observer="minmax")
            astate = lsq.init(jnp.asarray([float(x.min()), float(x.max())]),
                              aq)
            a_state = lsq.deploy_astate(astate, aq)
        cases.append((x, qt, a_state))
    qt_e = _export((4, 32, 16), 4, batch_dims=1)
    cases.append((jax.random.normal(jax.random.key(13), (4, 5, 32),
                                    jnp.float32), qt_e, None))

    def run_all():
        for x, qt, a_state in cases:
            jax.block_until_ready(
                kops.qtensor_matmul(x, qt, a_state=a_state, backend="xla"))

    run_all()  # warm: compiles each layout's kernel + eager glue once
    with no_retrace(0, xla_budget=0):
        run_all()
