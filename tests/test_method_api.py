"""Tests for the RoundingMethod protocol + per-site QuantRecipe rules.

Covers the API-redesign guarantees:
  - a third-party method registers with one decorator and flows through
    quantize_blocks with zero edits to core modules,
  - rule resolution (glob over site names, last match wins, default fallback),
  - mixed-precision reconstruction (W4 body + W8 first/last) exporting
    per-site bit-widths with recon error no worse than uniform W4,
  - checkpoint resume under different rules fails loudly.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import QuantRecipe, SiteRule, method_api, rtn
from repro.core.context import QuantCtx
from repro.core.qtensor import QTensor
from repro.core.quant_config import QuantConfig
from repro.core.reconstruct import (BlockHandle, Site, finalize_block,
                                    quantize_blocks, reconstruct_block,
                                    site_plans)


# --------------------------------------------------------------- test blocks
def make_mlp_block(key, name, d=32, d_hidden=48):
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (d, d_hidden), jnp.float32) * (d**-0.5),
        "w2": jax.random.normal(k2, (d_hidden, d), jnp.float32) * (d_hidden**-0.5),
    }

    def apply(p, x, ctx):
        h = jax.nn.gelu(ctx.linear(f"{name}.w1", x, p["w1"]))
        return ctx.linear(f"{name}.w2", h, p["w2"]) + x

    sites = {f"{name}.w1": Site(("w1",)), f"{name}.w2": Site(("w2",))}
    return BlockHandle(name, params, apply, sites)


def make_chain(n=3, d=32):
    keys = jax.random.split(jax.random.key(3), n)
    return [make_mlp_block(k, f"layers.{i}") for i, k in enumerate(keys)]


def chain_error(blocks, finalized, recipe, astates, x):
    y_fp, y_q = x, x
    for b in blocks:
        y_fp = b.apply(b.params, y_fp, QuantCtx(mode="fp"))
    for b, p in zip(blocks, finalized):
        y_q = b.apply(p, y_q, QuantCtx(mode="deploy", recipe=recipe,
                                       astates=astates))
    return float(jnp.mean((y_q - y_fp) ** 2))


def qtensor_bits(params):
    qts = [l for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, QTensor))
        if isinstance(l, QTensor)]
    return sorted({q.bits for q in qts}), len(qts)


# -------------------------------------------------- custom method end-to-end
@method_api.register_method("unit-toy")
class ToyMethod:
    """Third-party method: RTN grid + a learnable additive nudge on codes."""

    def init(self, w, qcfg, key=None):
        st = rtn.init(w, qcfg)
        st["nudge"] = jnp.zeros(w.shape, jnp.float32)
        return st

    def codes(self, w, state, qcfg, ste=True):
        base = rtn.codes(w, {k: state[k] for k in ("s1", "zero")}, qcfg, ste=ste)
        return jnp.clip(base + state["nudge"], qcfg.qmin, qcfg.qmax)

    def apply(self, w, state, qcfg):
        q = self.codes(w, state, qcfg, ste=True)
        return (state["s1"] * (q - state["zero"])).astype(w.dtype)

    def trainable(self, state):
        return {k: (k == "nudge") for k in state}

    def project(self, state):
        out = dict(state)
        out["nudge"] = jnp.clip(out["nudge"], -1.0, 1.0)
        return out

    def export(self, w, state, qcfg, dtype=jnp.bfloat16):
        from repro.core import qtensor
        q = jnp.round(self.codes(w, state, qcfg, ste=False))
        return qtensor.from_codes(q, state["s1"], state["zero"], qcfg,
                                  dtype=dtype)


def test_custom_method_registers_and_reconstructs():
    """One @register_method, zero edits elsewhere: validation, resolution,
    reconstruction, and export all pick up the new method."""
    assert "unit-toy" in method_api.available_methods()
    recipe = QuantRecipe(method="unit-toy", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=30, batch_size=8, lr=1e-2)
    blocks = make_chain(n=1)
    x = jax.random.normal(jax.random.key(0), (32, 32), jnp.float32)
    finalized, astates, reports = quantize_blocks(blocks, recipe, x)
    assert len(reports) == 1
    bits, n = qtensor_bits(finalized[0])
    assert bits == [4] and n == 2


def test_custom_method_missing_protocol_attr_raises():
    with pytest.raises(TypeError, match="missing required callables"):
        @method_api.register_method("unit-broken")
        class Broken:
            def init(self, w, qcfg, key=None):
                return {}


def test_unknown_method_rejected_by_recipe():
    with pytest.raises(ValueError, match="not registered"):
        QuantRecipe(method="does-not-exist")
    with pytest.raises(ValueError, match="not registered"):
        QuantRecipe(rules=("layers.0.*:method=does-not-exist",))


def test_methods_alias_is_gone():
    """The one-release deprecated `repro.core.methods` alias was removed:
    method_api is the single entry point."""
    with pytest.raises(ImportError):
        import repro.core.methods  # noqa: F401
    assert not hasattr(__import__("repro.core", fromlist=["core"]),
                       "methods")


# ------------------------------------------------------------ rule resolution
def test_rule_precedence_last_match_wins():
    recipe = QuantRecipe(
        method="flexround", w_bits=4, lr=3e-3,
        rules=("layers.*:w_bits=8",
               "layers.0.*:w_bits=6,method=rtn,lr=1e-4"))
    p0 = recipe.resolve("layers.0.w1")
    assert (p0.weight.bits, p0.method.name, p0.lr) == (6, "rtn", 1e-4)
    p1 = recipe.resolve("layers.1.w1")
    assert (p1.weight.bits, p1.method.name, p1.lr) == (8, "flexround", 3e-3)
    # default fallback: no rule matches
    pd = recipe.resolve("embed")
    assert (pd.weight.bits, pd.method.name) == (4, "flexround")


def test_rule_parsing_and_validation():
    r = SiteRule.parse("layers.0.*:w_bits=8,a_bits=none,w_symmetric=true")
    o = dict(r.overrides)
    assert o == {"w_bits": 8, "a_bits": None, "w_symmetric": True}
    with pytest.raises(ValueError, match="unknown recipe"):
        SiteRule.parse("layers.0.*:bogus_key=1")
    with pytest.raises(ValueError, match="not of the form"):
        SiteRule.parse("no-colon-here")
    # string rules are parsed on recipe construction
    recipe = QuantRecipe(rules=("*.w1:w_bits=2",))
    assert isinstance(recipe.rules[0], SiteRule)
    assert recipe.resolve("layers.3.w1").weight.bits == 2


def test_leaf_glob_matches_prefixless_sites():
    """Leaf-targeting patterns ('*.w_up') must cover sites with no
    'layers.<i>.' prefix (embeddings, lm_head) so allocator-emitted and
    hand-written rules can address them uniformly."""
    r = SiteRule.parse("*.w_up:w_bits=8")
    assert r.matches("layers.3.mlp.w_up")
    assert r.matches("w_up")          # prefix-less site
    assert not r.matches("mlp_w_up")  # leaf name must match exactly
    r2 = SiteRule.parse("*.embed:w_bits=8")
    assert r2.matches("embed")
    assert r2.matches("vision.embed")
    assert not r2.matches("token_embedding")
    # resolution end-to-end, both spellings
    recipe = QuantRecipe(w_bits=4, rules=("*.w1:w_bits=2", "embed:w_bits=8"))
    assert recipe.resolve("w1").weight.bits == 2
    assert recipe.resolve("layers.3.w1").weight.bits == 2
    assert recipe.resolve("embed").weight.bits == 8
    assert recipe.resolve("lm_head").weight.bits == 4  # untouched default
    # "layers.*" stays scoped: it must NOT leak onto top-level sites
    scoped = QuantRecipe(w_bits=4, rules=("layers.*:w_bits=8",))
    assert scoped.resolve("embed").weight.bits == 4


def test_exact_site_pattern_escapes_metachars():
    from repro.core.quant_config import exact_site_pattern
    r = SiteRule.make(exact_site_pattern("odd[site].*name"), w_bits=8)
    assert r.matches("odd[site].*name")
    assert not r.matches("odd[site].XXname")
    plain = SiteRule.make(exact_site_pattern("layers.0.wq"), w_bits=8)
    assert plain.matches("layers.0.wq")
    assert not plain.matches("layers.0.wqx")


def test_resolve_patches_batch_dims():
    """SitePlan replaces the old _qcfg_for/_wqcfg duplication: batch_dims
    flows from the Site into the weight QuantConfig."""
    recipe = QuantRecipe(w_bits=4)
    plan = recipe.resolve("layers.0.experts.w_up", Site(("w",), batch_dims=1))
    assert plan.weight.batch_dims == 1
    assert recipe.resolve("layers.0.w1").weight.batch_dims == 0
    # and via the QuantCtx keyword path
    assert recipe.resolve("layers.0.w1", batch_dims=1).weight.batch_dims == 1


def test_rules_can_disable_activation_quant_per_site():
    recipe = QuantRecipe(a_bits=8, rules=("layers.0.*:a_bits=none",))
    assert recipe.resolve("layers.0.w1").act is None
    act = recipe.resolve("layers.1.w1").act
    assert act is not None and act.bits == 8


# --------------------------------------------------------- mixed precision
def test_mixed_precision_w4_body_w8_ends():
    """The standard LLM recipe: W8 first/last, W4 body. Exported QTensors
    carry per-site bits; recon error is no worse than uniform W4."""
    blocks = make_chain(n=3)
    x = jax.random.normal(jax.random.key(1), (48, 32), jnp.float32)
    base = dict(method="flexround", w_bits=4, w_symmetric=True, a_bits=None,
                iters=60, batch_size=16, lr=3e-3)

    uniform = QuantRecipe(**base)
    fin_u, as_u, _ = quantize_blocks(blocks, uniform, x)

    mixed = QuantRecipe(**base, rules=("layers.0.*:w_bits=8",
                                       "layers.2.*:w_bits=8"))
    fin_m, as_m, _ = quantize_blocks(blocks, mixed, x)

    assert qtensor_bits(fin_m[0])[0] == [8]
    assert qtensor_bits(fin_m[1])[0] == [4]
    assert qtensor_bits(fin_m[2])[0] == [8]

    err_u = chain_error(blocks, fin_u, uniform, as_u, x)
    err_m = chain_error(blocks, fin_m, mixed, as_m, x)
    assert err_m <= err_u * 1.05  # more bits can't be meaningfully worse


def test_mixed_methods_in_one_block():
    """Different rounding methods may coexist inside one block."""
    block = make_mlp_block(jax.random.key(5), "layers.0")
    recipe = QuantRecipe(method="flexround", w_bits=4, w_symmetric=True,
                         a_bits=None, iters=20, batch_size=8,
                         rules=("layers.0.w2:method=rtn",))
    plans = site_plans(block, recipe)
    assert plans["layers.0.w1"].method.name == "flexround"
    assert plans["layers.0.w2"].method.name == "rtn"
    x = jax.random.normal(jax.random.key(6), (32, 32), jnp.float32)
    y = block.apply(block.params, x, QuantCtx(mode="fp"))
    ws, _, rep = reconstruct_block(block, recipe, x, y, jax.random.key(7))
    assert rep.err_after <= rep.err_before * 1.01  # flexround site learns
    fin = finalize_block(block, recipe, ws)
    assert qtensor_bits(fin)[0] == [4]


def test_checkpoint_resume_rejects_changed_rules(tmp_path):
    blocks = make_chain(n=2)
    x = jax.random.normal(jax.random.key(2), (32, 32), jnp.float32)
    base = dict(method="rtn", w_bits=4, w_symmetric=True, a_bits=None,
                iters=1, batch_size=8)
    recipe = QuantRecipe(**base)
    quantize_blocks(blocks, recipe, x, checkpoint_dir=str(tmp_path))

    from repro.checkpoint.checkpoint import PTQCheckpointer
    changed = QuantRecipe(**base, rules=("layers.0.*:w_bits=8",))
    with pytest.raises(ValueError, match="resume mismatch"):
        PTQCheckpointer(str(tmp_path)).load(blocks, changed)
    # unchanged rules resume fine
    resumed = PTQCheckpointer(str(tmp_path)).load(blocks, recipe)
    assert resumed is not None and resumed[0] == 2


def test_cli_choices_come_from_registry():
    """grep-proof: the launcher has no hard-coded method tuple."""
    import inspect
    from repro.launch import quantize as q
    src = inspect.getsource(q)
    assert "method_api.available_methods()" in src
    assert '"rtn", "adaround"' not in src
