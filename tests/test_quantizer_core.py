"""Unit tests for quantizer primitives, observers, and rounding methods."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaquant, adaround, flexround, method_api, observers, rtn
from repro.core import quantizer as qz
from repro.core.qtensor import dequantize_qtensor
from repro.core.quant_config import QuantConfig

jax.config.update("jax_enable_x64", False)

KEY = jax.random.key(0)


def _w(shape=(64, 32), scale=0.1, key=KEY):
    return jax.random.normal(key, shape, jnp.float32) * scale


# ------------------------------------------------------------------ primitives
def test_ste_round_grad_identity():
    g = jax.grad(lambda x: jnp.sum(qz.ste_round(x) * 3.0))(jnp.arange(4.0))
    np.testing.assert_allclose(g, 3.0 * np.ones(4))


def test_quantize_range():
    for sym in (True, False):
        qcfg = QuantConfig(bits=4, symmetric=sym)
        w = _w() * 10
        s, z = observers.minmax_scale(w, qcfg)
        q = qz.quantize(w, s, z, qcfg, ste=False)
        assert q.min() >= qcfg.qmin and q.max() <= qcfg.qmax


def test_rtn_error_bound():
    """|w - ŵ| <= s/2 for values inside the clipping range (minmax observer)."""
    qcfg = QuantConfig(bits=8, symmetric=False, observer="minmax")
    w = _w()
    s, z = observers.minmax_scale(w, qcfg)
    what = qz.fake_quant(w, s, z, qcfg, ste=False)
    assert float(jnp.max(jnp.abs(w - what))) <= float(s.reshape(())) * 0.5 + 1e-6


def test_mse_observer_beats_or_ties_minmax():
    # heavy-tailed weights: range shrinking should help
    w = jax.random.t(KEY, df=2.0, shape=(128, 64)).astype(jnp.float32)
    qcfg_mm = QuantConfig(bits=4, symmetric=True, observer="minmax")
    qcfg_ms = QuantConfig(bits=4, symmetric=True, observer="mse")
    s0, z0 = observers.init_scale(w, qcfg_mm)
    s1, z1 = observers.init_scale(w, qcfg_ms)
    e0 = jnp.mean((w - qz.fake_quant(w, s0, z0, qcfg_mm, ste=False)) ** 2)
    e1 = jnp.mean((w - qz.fake_quant(w, s1, z1, qcfg_ms, ste=False)) ** 2)
    assert float(e1) <= float(e0) + 1e-9


def test_per_channel_shapes():
    qcfg = QuantConfig(bits=8, granularity="per_channel")
    w = _w((16, 8))
    s, z = observers.init_scale(w, qcfg)
    assert s.shape == (1, 8)
    qcfg_b = QuantConfig(bits=8, granularity="per_channel", batch_dims=1)
    w3 = _w((4, 16, 8))
    s3, _ = observers.init_scale(w3, qcfg_b)
    assert s3.shape == (4, 1, 8)


# ------------------------------------------------------------------ flexround
def test_flexround_init_equals_rtn():
    for sym, gran in [(True, "per_tensor"), (False, "per_tensor"),
                      (False, "per_channel")]:
        qcfg = QuantConfig(bits=4, symmetric=sym, granularity=gran)
        w = _w()
        st_f = flexround.init(w, qcfg)
        st_r = rtn.init(w, qcfg)
        np.testing.assert_allclose(flexround.apply(w, st_f, qcfg),
                                   rtn.apply(w, st_r, qcfg), rtol=0, atol=0)


def test_flexround_conv_has_s4():
    qcfg = QuantConfig(bits=4, symmetric=True)
    w = _w((3, 3, 8, 16))
    st = flexround.init(w, qcfg)
    assert st["s3"].shape == (1, 1, 1, 16)
    assert st["s4"].shape == (1, 1, 8, 1)
    out = flexround.apply(w, st, qcfg)
    assert out.shape == w.shape


def test_proposition_3_1_gradient_identity():
    """dL/dS2 == -(W / (S2..)^2 / s1... ) * s1 * dL/dq  — check the exact
    reciprocal-rule form: grad wrt s2 equals -(W * g / (s1 * s2^2 * s3)) for
    in-range weights, where g = dL/dŴ."""
    qcfg = QuantConfig(bits=8, symmetric=True, observer="minmax")  # nothing clips
    w = _w((32, 16), scale=0.05)
    st = flexround.init(w, qcfg)
    # move away from init so s2 != 1 uniformly
    st = dict(st, s2=st["s2"] * jnp.exp(0.01 * jax.random.normal(KEY, w.shape)))
    tgt = _w((32, 16), key=jax.random.key(1))

    def loss(s2):
        what = flexround.apply(w, dict(st, s2=s2), qcfg)
        return 0.5 * jnp.sum((what - tgt) ** 2)

    g_auto = jax.grad(loss)(st["s2"])
    what = flexround.apply(w, st, qcfg)
    dL_dWhat = what - tgt
    s1, s2, s3 = st["s1"], st["s2"], st["s3"]
    # only strictly-in-range entries carry the reciprocal-rule gradient;
    # clipped entries have zero autodiff grad (hard clamp), as in the paper.
    codes = w / (s1 * s2 * s3)
    inr = (codes > qcfg.qmin + 0.5) & (codes < qcfg.qmax - 0.5)
    # dŴ/ds2 = s1 * W/(s1*s3) * d(1/s2)/ds2 = -W/(s2^2 s3)
    g_manual = jnp.where(inr, -(w / (s2**2 * s3)) * dL_dWhat, 0.0)
    np.testing.assert_allclose(np.where(inr, g_auto, 0.0), g_manual,
                               rtol=1e-4, atol=1e-6)
    # and the proportionality to W the paper highlights:
    nz = inr & (jnp.abs(dL_dWhat) > 1e-6) & (jnp.abs(w) > 1e-6)
    sign_ok = jnp.sign(g_auto) == -jnp.sign(w * dL_dWhat)
    assert float(jnp.mean(jnp.where(nz, sign_ok, True))) > 0.99


def test_flexround_can_shift_more_than_one_grid():
    """FlexRound with S' != 1 reaches codes beyond RTN±1 (paper Fig. 3-5);
    AdaRound structurally cannot."""
    qcfg = QuantConfig(bits=8, symmetric=True)
    w = _w((32, 16), scale=0.2)
    st = flexround.init(w, qcfg)
    rtn_codes = jnp.round(w / st["s1"])
    st2 = dict(st, s2=st["s2"] * 0.7)  # divide less -> bigger codes
    fr_codes = flexround.codes(w, st2, qcfg, ste=False)
    shifts = jnp.abs(fr_codes - rtn_codes)
    assert float(jnp.max(shifts)) > 1.0

    ada = adaround.init(w, qcfg)
    lo = jnp.floor(w / ada["s1"])
    inr = (lo >= qcfg.qmin) & (lo + 1 <= qcfg.qmax)  # ignore clip saturation
    for v in (-10.0, 10.0):
        st_a = dict(ada, v=jnp.full_like(w, v))
        q = adaround._codes(w, st_a, qcfg, hard=True)
        # up or down only (within the grid)
        assert float(jnp.max(jnp.where(inr, jnp.abs(q - lo), 0.0))) <= 1.0


# ------------------------------------------------------- method common checks
@pytest.mark.parametrize("name", ["rtn", "adaround", "adaquant", "flexround"])
@pytest.mark.parametrize("sym,gran", [(True, "per_tensor"), (False, "per_channel")])
def test_method_roundtrip_and_export(name, sym, gran):
    qcfg = QuantConfig(bits=4, symmetric=sym, granularity=gran)
    m = method_api.get_method(name)
    w = _w((16, 8))
    st = m.init(w, qcfg)
    what = m.apply(w, st, qcfg)
    assert what.shape == w.shape and what.dtype == w.dtype
    assert not bool(jnp.any(jnp.isnan(what)))
    qt = m.export(w, st, qcfg, dtype=jnp.float32)
    wd = dequantize_qtensor(qt)
    assert wd.shape == w.shape
    # export == apply at init for rtn/flexround (no soft states)
    if name in ("rtn", "flexround"):
        np.testing.assert_allclose(wd, what, rtol=1e-5, atol=1e-6)


def test_int4_packing_roundtrip():
    qcfg = QuantConfig(bits=4, symmetric=False)
    w = _w((16, 8))
    st = rtn.init(w, qcfg)
    qt = rtn.export(w, st, qcfg, dtype=jnp.float32)
    assert qt.packed and qt.codes.shape == (8, 8)
    np.testing.assert_allclose(dequantize_qtensor(qt), rtn.apply(w, st, qcfg),
                               rtol=1e-5, atol=1e-6)


def test_adaquant_learns_scale():
    qcfg = QuantConfig(bits=4, symmetric=True)
    w = _w()
    st = adaquant.init(w, qcfg)
    g = jax.grad(lambda s1: jnp.sum(adaquant.apply(w, dict(st, s1=s1), qcfg)))(
        st["s1"])
    assert float(jnp.sum(jnp.abs(g))) > 0.0  # s1 gets gradient (unlike AdaRound)


def test_adaround_regularizer_anneals():
    from repro.core.quant_config import QuantRecipe
    qcfg = QuantConfig(bits=4, symmetric=True)
    recipe = QuantRecipe(method="adaround", iters=100)
    w = _w()
    st = adaround.init(w, qcfg)
    r_warm = adaround.loss_extra(st, qcfg, 0, recipe)
    r_mid = adaround.loss_extra(st, qcfg, 50, recipe)
    assert float(r_warm) == 0.0 and float(r_mid) > 0.0
