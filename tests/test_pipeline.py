"""Data pipeline: deterministic per-host shards, straggler assembly, and the
loud failure modes (divisibility / shard-shape mismatches must raise
ValueError naming the offender — a bare assert vanishes under ``python -O``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (CalibrationSet, StragglerPolicy, SyntheticTokens,
                        assemble_global_batch)


def _src(**kw):
    return SyntheticTokens(vocab=64, seq_len=8, seed=0, **kw)


def test_batch_is_pure_per_host_function():
    src = _src()
    a = src.batch(3, 8, host=1, n_hosts=4)
    b = src.batch(3, 8, host=1, n_hosts=4)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    other = src.batch(3, 8, host=2, n_hosts=4)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(other["tokens"]))
    assert a["tokens"].shape == (2, 8)  # 8 global / 4 hosts


def test_batch_divisibility_raises_valueerror():
    with pytest.raises(ValueError, match="batch_size=10.*n_hosts=4"):
        _src().batch(0, 10, host=0, n_hosts=4)


def test_batch_host_out_of_range_raises():
    with pytest.raises(ValueError, match="host index 4.*n_hosts=4"):
        _src().batch(0, 8, host=4, n_hosts=4)
    with pytest.raises(ValueError, match="host index -1"):
        _src().batch(0, 8, host=-1, n_hosts=4)


def _shards(n_hosts=4, local=2, seq=8):
    src = _src()
    return [
        {k: np.asarray(v)
         for k, v in src.batch(0, local * n_hosts, host=h,
                               n_hosts=n_hosts).items()}
        for h in range(n_hosts)
    ]


def test_assemble_all_present():
    shards = _shards()
    batch, weight = assemble_global_batch(shards, StragglerPolicy())
    assert batch["tokens"].shape == (8, 8)
    np.testing.assert_array_equal(np.asarray(weight), np.ones(8, np.float32))


def test_assemble_dropped_shard_zero_filled_and_masked():
    shards = _shards()
    shards[2] = None
    batch, weight = assemble_global_batch(
        shards, StragglerPolicy(min_fraction=0.5))
    assert batch["tokens"].shape == (8, 8)
    np.testing.assert_array_equal(
        np.asarray(weight), np.asarray([1, 1, 1, 1, 0, 0, 1, 1], np.float32))
    np.testing.assert_array_equal(np.asarray(batch["tokens"][4:6]),
                                  np.zeros((2, 8), np.int32))


def test_assemble_below_min_fraction_times_out():
    shards = _shards()
    shards[0] = shards[1] = None
    with pytest.raises(TimeoutError):
        assemble_global_batch(shards, StragglerPolicy(min_fraction=0.75))
    with pytest.raises(RuntimeError):
        assemble_global_batch([None, None], StragglerPolicy())


def test_assemble_shape_mismatch_names_host():
    shards = _shards()
    shards[3] = {k: v[:1] for k, v in shards[3].items()}  # truncated shard
    with pytest.raises(ValueError, match=r"host 3 .*'labels'|'tokens'"):
        assemble_global_batch(shards, StragglerPolicy())


def test_assemble_key_mismatch_names_host():
    shards = _shards()
    del shards[1]["labels"]
    with pytest.raises(ValueError, match="host 1 shard keys"):
        assemble_global_batch(shards, StragglerPolicy())


def test_assemble_proto_is_first_present_shard():
    """With host 0 dropped, validation compares against the first *present*
    host — the error must not blame the missing one."""
    shards = _shards()
    shards[0] = None
    bad = {k: np.concatenate([v, v]) for k, v in shards[2].items()}
    shards[2] = bad
    with pytest.raises(ValueError, match="host 2 .* host 1"):
        assemble_global_batch(shards, StragglerPolicy(min_fraction=0.5))


def test_build_sharded_calibration_weight_semantics():
    src = _src()
    cal, weight = CalibrationSet.build_sharded(src, 16, n_hosts=4)
    assert len(cal) == 16 and cal.tokens.shape == (16, 8)
    assert float(jnp.sum(weight)) == 16.0

    cal2, weight2 = CalibrationSet.build_sharded(
        src, 16, n_hosts=4, drop_hosts=(1,),
        policy=StragglerPolicy(min_fraction=0.5))
    assert len(cal2) == 16
    w = np.asarray(weight2)
    assert w[4:8].sum() == 0 and w.sum() == 12
    # present hosts' samples are identical with and without the drop (pure
    # per-host batch function: no resharding of survivors)
    np.testing.assert_array_equal(np.asarray(cal2.tokens[:4]),
                                  np.asarray(cal.tokens[:4]))
    np.testing.assert_array_equal(np.asarray(cal2.tokens[8:]),
                                  np.asarray(cal.tokens[8:]))
