"""Hypothesis property-based tests on quantization invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (installed in CI)")
import hypothesis.extra.numpy as hnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import flexround, observers, rtn
from repro.core import quantizer as qz
from repro.core.qtensor import dequantize_qtensor
from repro.core.quant_config import QuantConfig

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

weights = hnp.arrays(
    np.float32, st.tuples(st.integers(2, 24), st.integers(2, 24)),
    elements=st.floats(-10, 10, width=32, allow_nan=False))

qconfigs = st.builds(
    QuantConfig,
    bits=st.integers(2, 8),
    symmetric=st.booleans(),
    granularity=st.sampled_from(["per_tensor", "per_channel"]),
    observer=st.sampled_from(["minmax", "mse"]),
)


@hypothesis.given(weights, qconfigs)
def test_fake_quant_idempotent(w, qcfg):
    """quant(dequant(quant(x))) == quant(x) — fake-quant is a projection."""
    w = jnp.asarray(w)
    s, z = observers.init_scale(w, qcfg)
    w1 = qz.fake_quant(w, s, z, qcfg, ste=False)
    w2 = qz.fake_quant(w1, s, z, qcfg, ste=False)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-5, atol=1e-6)


@hypothesis.given(weights, qconfigs)
def test_observer_scale_positive_and_codes_in_range(w, qcfg):
    w = jnp.asarray(w)
    s, z = observers.init_scale(w, qcfg)
    assert bool(jnp.all(s > 0))
    q = qz.quantize(w, s, z, qcfg, ste=False)
    assert float(q.min()) >= qcfg.qmin and float(q.max()) <= qcfg.qmax


@hypothesis.given(weights, st.integers(2, 8), st.booleans())
def test_minmax_error_bound(w, bits, sym):
    """RTN error <= s/2 inside the representable range (minmax observer)."""
    qcfg = QuantConfig(bits=bits, symmetric=sym, observer="minmax")
    w = jnp.asarray(w)
    s, z = observers.init_scale(w, qcfg)
    what = qz.fake_quant(w, s, z, qcfg, ste=False)
    err = jnp.abs(w - what)
    if not sym:
        assert float(jnp.max(err)) <= float(jnp.max(s)) * 0.5 + 1e-4
    else:
        assert float(jnp.max(err)) <= float(jnp.max(s)) * 0.5 + 1e-4


@hypothesis.given(weights, qconfigs)
def test_flexround_init_is_rtn(w, qcfg):
    w = jnp.asarray(w)
    st_f = flexround.init(w, qcfg)
    st_r = rtn.init(w, qcfg)
    np.testing.assert_array_equal(
        np.asarray(flexround.apply(w, st_f, qcfg)),
        np.asarray(rtn.apply(w, st_r, qcfg)))


@hypothesis.given(weights, qconfigs, st.floats(0.3, 3.0))
def test_flexround_scale_invariance_of_grid(w, qcfg, alpha):
    """Scaling S' leaves the reconstruction GRID unchanged (outputs are
    always integer multiples of s1 shifted by zero)."""
    w = jnp.asarray(w)
    st_ = flexround.init(w, qcfg)
    st2 = dict(st_, s2=st_["s2"] * alpha)
    what = flexround.apply(w, st2, qcfg)
    codes = what / st_["s1"]
    np.testing.assert_allclose(np.asarray(codes),
                               np.round(np.asarray(codes)), atol=1e-3)


@hypothesis.given(weights, st.integers(2, 8), st.booleans())
def test_qtensor_export_roundtrip(w, bits, sym):
    qcfg = QuantConfig(bits=bits, symmetric=sym, observer="minmax")
    w = jnp.asarray(w)
    if bits <= 4 and w.shape[0] % 2:
        w = jnp.pad(w, ((0, 1), (0, 0)))
    st_ = rtn.init(w, qcfg)
    qt = rtn.export(w, st_, qcfg, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dequantize_qtensor(qt)),
                               np.asarray(rtn.apply(w, st_, qcfg)),
                               rtol=1e-5, atol=1e-5)


@hypothesis.given(hnp.arrays(np.float32, st.integers(4, 300),
                             elements=st.floats(-100, 100, width=32,
                                                allow_nan=False)))
def test_int8_moment_roundtrip_bounded(g):
    from repro.optim.adam import _dq8, _q8
    g = jnp.asarray(g)
    q, s = _q8(g)
    d = _dq8(q, s, g.shape)
    assert float(jnp.max(jnp.abs(g - d))) <= float(jnp.max(jnp.abs(g))) / 127 \
        + 1e-6


@hypothesis.given(st.integers(1, 4096), st.integers(1, 2048))
def test_moe_group_divides(tokens, target):
    from repro.models.moe import _pick_group
    n = _pick_group(tokens, target)
    assert 1 <= n <= max(1, min(target, tokens)) and tokens % n == 0
