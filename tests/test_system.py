"""End-to-end system test: train -> PTQ (FlexRound) -> quantized serving.

The full product path at smoke scale: pretrain a tiny LM on the synthetic
corpus, quantize block-by-block with FlexRound (paper recipe), export integer
weights, and serve greedy decodes — asserting (a) quantized ppl ≈ fp ppl,
(b) FlexRound < RTN, (c) int-weight serving emits the same greedy tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import QuantRecipe
from repro.core.context import QuantCtx
from repro.core.reconstruct import quantize_blocks
from repro.data import CalibrationSet, SyntheticTokens
from repro.models import build_model
from repro.optim.adam import AdamConfig, adam_init, adam_update

CFG = ArchConfig(name="sys-test", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
                 dtype="float32", attn_chunk=32, xent_chunk=32, remat=False)
SEQ, BATCH, STEPS = 32, 16, 120


def _train():
    model = build_model(CFG)
    src = SyntheticTokens(vocab=CFG.vocab, seq_len=SEQ, seed=0)
    params = model.init(jax.random.key(0))
    opt_cfg = AdamConfig(lr=5e-3, grad_clip=1.0)
    opt = adam_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            loss, _ = model.loss(p, batch, QuantCtx(mode="fp"))
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adam_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    first = last = None
    for i in range(STEPS):
        params, opt, loss = step(params, opt, src.batch(i, BATCH))
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first, "training must reduce loss"
    return model, params, src


def _ppl(model, params, src, ctx):
    tot = 0.0
    for i in range(4):
        loss, _ = model.loss(params, src.batch(9_000 + i, BATCH), ctx)
        tot += float(loss)
    return float(np.exp(tot / 4))


def test_end_to_end_train_quantize_serve():
    model, params, src = _train()
    fp_ppl = _ppl(model, params, src, QuantCtx(mode="fp"))

    cal = CalibrationSet.build(src, 32)
    results = {}
    for method, iters in (("rtn", 1), ("flexround", 120)):
        recipe = QuantRecipe(method=method, w_bits=4, w_symmetric=True,
                             a_bits=None, iters=iters, lr=3e-3, batch_size=8)
        x0, blocks, assemble = model.quant_blocks(params, cal.tokens)
        fin, astates, _ = quantize_blocks(blocks, recipe, x0,
                                          as_qtensor=False)
        qp = assemble(fin)
        results[method] = _ppl(model, qp, src,
                               QuantCtx(mode="deploy", recipe=recipe,
                                        astates=astates))
    assert results["flexround"] < results["rtn"], \
        f"flexround {results['flexround']} !< rtn {results['rtn']}"
    assert results["flexround"] < fp_ppl * 1.5  # close to full precision

    # integer-weight serving path: greedy decode matches fake-quant forward
    recipe = QuantRecipe(method="flexround", w_bits=8, a_bits=None,
                         w_granularity="per_channel", iters=40, lr=3e-3,
                         batch_size=8)
    x0, blocks, assemble = model.quant_blocks(params, cal.tokens)
    fin, astates, _ = quantize_blocks(blocks, recipe, x0, as_qtensor=True)
    qp = assemble(fin)
    ctx = QuantCtx(mode="deploy")
    toks = src.batch(123, 2)["tokens"]
    cache = model.init_cache(2, SEQ + 4)
    _, cache = model.prefill(qp, toks, cache, ctx)
    logits, cache = model.decode_step(qp, toks[:, -1:], cache, jnp.int32(SEQ),
                                      ctx)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
